#!/usr/bin/env python3
"""BENCH_hotpath.json regression smoke (ISSUE 7, satellite 5).

Run after `cargo bench --bench coordinator_hotpath` emits
BENCH_hotpath.json. Two gates:

1. completeness — every scenario key the bench has historically emitted
   must still be present (a bench refactor that silently drops a
   scenario reads as "no regression" forever after);
2. the headline FlashCAM claim — the fused streaming kernel must beat
   the PR-4 sparse_incremental pipeline per decode step at the largest
   context (n = 4096), where the O(n·d) scoring loop dominates and the
   u64 word-parallel pass has the most room.

Stdlib only; exits non-zero with a readable report on any violation.
"""

import json
import sys

EXPECTED_KEYS = [
    # long-context recipe x context-length matrix (ISSUEs 4, 7)
    *[
        f"long_context_{recipe}_n{n}"
        for recipe in (
            "dense_full_repack",
            "dense_incremental",
            "sparse_incremental",
            "fused_incremental",
        )
        for n in (256, 1024, 4096)
    ],
    # standing-scheduler open-loop burst (ISSUE 6)
    "bursty_open_loop_16sess_q8",
]

FUSED = "long_context_fused_incremental_n4096"
SPARSE = "long_context_sparse_incremental_n4096"


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_hotpath.json"
    try:
        with open(path, encoding="utf-8") as f:
            bench = json.load(f)
    except OSError as e:
        print(f"check_bench: cannot read {path}: {e}", file=sys.stderr)
        return 1

    failures = []
    missing = [k for k in EXPECTED_KEYS if k not in bench]
    if missing:
        failures.append(f"missing scenario keys: {', '.join(missing)}")
    for key, ns in bench.items():
        if not isinstance(ns, (int, float)) or ns <= 0:
            failures.append(f"scenario {key!r}: non-positive ns/step {ns!r}")

    if not missing:
        fused, sparse = bench[FUSED], bench[SPARSE]
        if fused >= sparse:
            failures.append(
                f"fused kernel must beat the sparse pipeline at n=4096: "
                f"{FUSED} = {fused:.1f} ns/step >= {SPARSE} = {sparse:.1f} ns/step"
            )
        else:
            print(
                f"check_bench: fused n=4096 {fused:.1f} ns/step vs sparse "
                f"{sparse:.1f} ns/step ({sparse / fused:.2f}x)"
            )

    if failures:
        for f_ in failures:
            print(f"check_bench: FAIL: {f_}", file=sys.stderr)
        return 1
    print(f"check_bench: OK ({len(EXPECTED_KEYS)} scenarios present)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
