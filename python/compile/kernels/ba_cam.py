"""BA-CAM association kernel (Layer 1, Pallas).

This is the paper's analog hot spot — the voltage-domain Binary-Attention
CAM computing ``QK^T`` as a Hamming-similarity search — re-thought for the
TPU (DESIGN.md §Hardware-Adaptation):

* The matchline charge-share (XNOR + analog accumulate) becomes a ±1 matmul
  on the MXU: for ±1 vectors ``q . k = 2*matches - d_k``, exactly the
  affine map the paper's multiply-subtract unit applies to the ADC code.
* The HBM->VMEM ``BlockSpec`` walk reproduces the CAM tiling of Fig. 4:
  the grid axes are (query tile ①②, key tile ④-horizontal, d_k tile
  ④-vertical); the innermost axis accumulates into the output block the way
  the paper's accumulation register does across vertical tiles.
* The 6-bit SAR ADC is modelled *per tile* inside the kernel: each
  ``CAM_H x CAM_W`` tile's analog partial sum is quantised before the
  digital accumulation, matching the hardware (ADC sits on the matchline,
  the accumulation register is digital).

The kernel is lowered with ``interpret=True`` — real-TPU Pallas emits a
Mosaic custom-call the CPU PJRT plugin cannot execute; structure (tiling,
VMEM residency) is what we optimise, and EXPERIMENTS.md §Perf estimates the
TPU roofline from the block shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import ADC_BITS, CAM_H, CAM_W


def _bacam_tile_kernel(q_ref, k_ref, o_ref, *, cam_w: int, adc_bits: int):
    """One grid step: associate a (Bt, cam_w) query tile against a
    (CAM_H, cam_w) key tile; quantise through the per-tile ADC; accumulate.

    Grid = (query tiles, key tiles, d_k tiles); the d_k axis is innermost so
    the output block stays resident while vertical tiles accumulate
    (Fig. 4 step ④-vertical / the association stage's accumulation register).
    """
    d = pl.program_id(2)
    # Binarise in VMEM: the CAM stores sign bits; {-1,+1} keeps the MXU path.
    qb = jnp.where(q_ref[...] >= 0, 1.0, -1.0)
    kb = jnp.where(k_ref[...] >= 0, 1.0, -1.0)
    # Matchline: dot in [-cam_w, cam_w]  <=>  voltage (dot+W)/(2W) in [0,1].
    dot = jnp.dot(qb, kb.T, preferred_element_type=jnp.float32)
    volt = (dot + cam_w) / (2.0 * cam_w)
    # Per-tile 6-bit SAR ADC + multiply-subtract: s = 2*ADC(v) - CAM_W.
    levels = 2**adc_bits
    code = jnp.clip(jnp.round(volt * levels), 0.0, float(levels))
    s = 2.0 * code * (cam_w / levels) - cam_w

    @pl.when(d == 0)
    def _init():
        o_ref[...] = s

    @pl.when(d > 0)
    def _acc():
        o_ref[...] += s


@functools.partial(
    jax.jit, static_argnames=("cam_h", "cam_w", "adc_bits", "query_block")
)
def bacam_scores_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    cam_h: int = CAM_H,
    cam_w: int = CAM_W,
    adc_bits: int = ADC_BITS,
    query_block: int = 8,
) -> jnp.ndarray:
    """Binary attention scores via the BA-CAM Pallas kernel.

    ``q``: (B, d_k) real-valued queries; ``k``: (N, d_k) real-valued keys.
    Returns quantised signed scores (B, N) in [-d_k, d_k].

    ``N`` must divide by ``cam_h`` and ``d_k`` by ``cam_w`` (the paper
    assumes the same and pads otherwise; use :func:`bacam_scores_padded`
    for arbitrary shapes).
    """
    b, d_k = q.shape
    n, d_k2 = k.shape
    assert d_k == d_k2, f"d_k mismatch: {d_k} vs {d_k2}"
    assert n % cam_h == 0, f"N={n} not a multiple of CAM_H={cam_h}"
    assert d_k % cam_w == 0, f"d_k={d_k} not a multiple of CAM_W={cam_w}"
    bt = min(query_block, b)
    assert b % bt == 0, f"B={b} not a multiple of query_block={bt}"

    grid = (b // bt, n // cam_h, d_k // cam_w)
    kernel = functools.partial(_bacam_tile_kernel, cam_w=cam_w, adc_bits=adc_bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, cam_w), lambda bi, ni, di: (bi, di)),
            pl.BlockSpec((cam_h, cam_w), lambda bi, ni, di: (ni, di)),
        ],
        out_specs=pl.BlockSpec((bt, cam_h), lambda bi, ni, di: (bi, ni)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(q, k)


def bacam_scores_padded(
    q: jnp.ndarray,
    k: jnp.ndarray,
    cam_h: int = CAM_H,
    cam_w: int = CAM_W,
    adc_bits: int = ADC_BITS,
) -> jnp.ndarray:
    """Arbitrary-shape wrapper: zero-pads d_k and N up to tile multiples.

    d_k padding appends matching bits to *both* q and k (+1 vs +1), which
    shifts every tile score by the same constant; we subtract it back out,
    mirroring how a padded CAM column contributes a fixed charge offset.
    Key padding appends rows whose scores are discarded.
    """
    b, d_k = q.shape
    n, _ = k.shape
    pad_d = (-d_k) % cam_w
    pad_n = (-n) % cam_h
    qp = jnp.pad(q, ((0, 0), (0, pad_d)), constant_values=1.0)
    kp = jnp.pad(k, ((0, pad_n), (0, pad_d)), constant_values=1.0)
    s = bacam_scores_pallas(qp, kp, cam_h, cam_w, adc_bits, query_block=1 if b % 8 else 8)
    # Padded key rows see `pad_d` guaranteed matches; padded d_k bits add a
    # constant +pad_d to every score. Remove the offset, drop padded rows.
    return s[:, :n] - float(pad_d)


def camformer_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    group: int = CAM_H,
    stage1_k: int = 2,
    final_k: int = 32,
    adc_bits: int = ADC_BITS,
) -> jnp.ndarray:
    """Eq. 1 end-to-end with the Pallas association kernel.

    Association (scores) runs in the BA-CAM kernel; normalisation
    (two-stage top-k + LUT softmax) and BF16 contextualization are the
    paper's digital stages and stay as jnp ops fused by XLA.
    """
    squeeze = q.ndim == 1
    qb = q[None, :] if squeeze else q
    scores = bacam_scores_padded(qb, k, cam_h=group, adc_bits=adc_bits)
    mask = ref.two_stage_topk_mask(scores, group, stage1_k, final_k)
    a_hat = ref.lut_softmax(scores, mask, q.shape[-1])
    out = (a_hat.astype(jnp.bfloat16) @ v.astype(jnp.bfloat16)).astype(jnp.float32)
    return out[0] if squeeze else out
