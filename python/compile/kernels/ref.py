"""Pure-jnp reference oracles for CAMformer attention.

These are the CORE correctness signal: the Pallas kernel
(:mod:`compile.kernels.ba_cam`), the L2 model and the Rust functional model
(``rust/src/accuracy/``) are all validated against these functions.

The reference chain mirrors the paper's datapath (Sec. II-III):

    binarise(Q, K)  ->  BA-CAM scores (Hamming similarity, analog voltage)
                    ->  6-bit SAR ADC   (s = 2*ADC(v) - CAM_W, Sec. II-B1)
                    ->  two-stage top-k (top-k1 per group of g, then Top-K)
                    ->  LUT softmax     (exp(x/sqrt(d_k)))
                    ->  BF16 sparse contextualization (A_hat @ V)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Matches the paper's 16x64 BA-CAM array (Sec. III-B1).
CAM_H = 16  # keys per tile == stage-1 group size g
CAM_W = 64  # bits per row == d_k
ADC_BITS = 6


def binarize(x: jnp.ndarray) -> jnp.ndarray:
    """Sign-binarise to {-1, +1} (HAD-style Q/K binarisation).

    Zero maps to +1 so the output is always full-scale binary.
    """
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def matchline_voltage(q_bits: jnp.ndarray, k_bits: jnp.ndarray) -> jnp.ndarray:
    """Analog matchline voltage in [0, 1]: fraction of matching bits.

    ``q_bits``: (d_k,) in {-1,+1}; ``k_bits``: (N, d_k) in {-1,+1}.
    Each matching bit leaves one precharged 22 fF capacitor high, so after
    charge sharing V_ML = matches / d_k (Fig. 2 / Fig. 3a).
    """
    d_k = q_bits.shape[-1]
    dot = k_bits @ q_bits  # in [-d_k, d_k]; dot = 2*matches - d_k
    matches = (dot + d_k) / 2.0
    return matches / d_k


def adc_quantize(v: jnp.ndarray, d_k: int, bits: int = ADC_BITS) -> jnp.ndarray:
    """6-bit SAR ADC + fixed multiply-subtract: V_ML in [0,1] -> signed score
    ``s = 2*ADC(v) - CAM_W`` mapping [0,1] -> [-d_k, d_k] (Sec. II-B1).

    With ``bits`` = 6 and d_k = 64 the ADC resolves every possible match
    count, so quantisation is exact ("ADC precision covers the full match
    range", Sec. III-B1). For d_k > 2**bits the score quantises.
    """
    levels = 2**bits  # SAR codes span the full match range [0, d_k]
    code = jnp.clip(jnp.round(v * levels), 0, levels)
    matches = code * (d_k / levels)  # code -> match count
    return 2.0 * matches - d_k


def bacam_scores(
    q: jnp.ndarray,
    k: jnp.ndarray,
    adc_bits: int = ADC_BITS,
    noise_sigma: float = 0.0,
    noise_key: jax.Array | None = None,
) -> jnp.ndarray:
    """Full BA-CAM association path: binarise -> matchline -> ADC.

    ``q``: (..., d_k) real-valued; ``k``: (N, d_k) real-valued.
    Returns signed quantised scores (..., N) in [-d_k, d_k].
    ``noise_sigma`` adds Gaussian matchline voltage noise (PVT model,
    Fig. 3b; the paper simulates sigma = 1.4%).
    """
    d_k = q.shape[-1]
    qb = binarize(q)
    kb = binarize(k)
    v = (qb @ kb.T + d_k) / (2.0 * d_k)  # matchline voltage in [0, 1]
    if noise_sigma > 0.0:
        assert noise_key is not None, "noise_sigma > 0 requires noise_key"
        v = v + noise_sigma * jax.random.normal(noise_key, v.shape, v.dtype)
        v = jnp.clip(v, 0.0, 1.0)
    return adc_quantize(v, d_k, adc_bits)


def bacam_scores_tiled(
    q: jnp.ndarray,
    k: jnp.ndarray,
    cam_w: int = CAM_W,
    adc_bits: int = ADC_BITS,
) -> jnp.ndarray:
    """BA-CAM scores with *per-tile* ADC quantisation — the exact hardware
    model for d_k > CAM_W (Fig. 4 vertical tiling + accumulation register).

    Each CAM_W-wide tile's matchline voltage is digitised by its own 6-bit
    SAR conversion; the signed tile scores are then accumulated digitally.
    For d_k <= CAM_W this equals :func:`bacam_scores`.
    """
    d_k = q.shape[-1]
    assert d_k % cam_w == 0, f"d_k={d_k} not a multiple of CAM_W={cam_w}"
    qb = binarize(q)
    kb = binarize(k)
    total = jnp.zeros(q.shape[:-1] + (k.shape[0],), q.dtype)
    for t in range(d_k // cam_w):
        sl = slice(t * cam_w, (t + 1) * cam_w)
        v = (qb[..., sl] @ kb[:, sl].T + cam_w) / (2.0 * cam_w)
        total = total + adc_quantize(v, cam_w, adc_bits)
    return total


def _topk_mask_lastaxis(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask keeping exactly the k largest entries of the last axis
    (ties broken toward lower indices, matching a stable hardware sorter)."""
    order = jnp.argsort(-scores, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    return ranks < k


def two_stage_topk_mask(
    scores: jnp.ndarray, group: int = CAM_H, stage1_k: int = 2, final_k: int = 32
) -> jnp.ndarray:
    """Hierarchical two-stage top-k (Sec. III-C4).

    Stage 1 keeps the top ``stage1_k`` per contiguous ``group`` of keys (the
    bitonic Top-2 per 16-key CAM tile); everything else is dropped. Stage 2
    keeps the global top ``final_k`` among stage-1 survivors (the 64-input
    bitonic Top-32 block). Returns a boolean mask over the last axis.
    """
    *lead, n = scores.shape
    assert n % group == 0, f"N={n} must be a multiple of group={group}"
    g = n // group
    tiled = scores.reshape(*lead, g, group)
    survive = _topk_mask_lastaxis(tiled, stage1_k).reshape(*lead, n)
    masked = jnp.where(survive, scores, -jnp.inf)
    keep = _topk_mask_lastaxis(masked, final_k) & survive
    return keep


def single_stage_topk_mask(scores: jnp.ndarray, final_k: int = 32) -> jnp.ndarray:
    """HAD-style single-stage global Top-k mask (Tables III/IV baseline)."""
    return _topk_mask_lastaxis(scores, final_k)


def lut_softmax(scores: jnp.ndarray, mask: jnp.ndarray, d_k: int) -> jnp.ndarray:
    """Softmax over masked (top-k) scores with the paper's 1/sqrt(d_k) scale.

    The Normalization stage computes exp(x / sqrt(d_k)) via a 512 B LUT and
    normalises with one BF16 accumulator + one BF16 divider (Sec. III-B2).
    Masked-out entries get probability 0; kept entries sum to 1.
    """
    x = scores / jnp.sqrt(jnp.asarray(d_k, scores.dtype))
    x = jnp.where(mask, x, -jnp.inf)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    e = jnp.where(mask, e, 0.0)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def camformer_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    group: int = CAM_H,
    stage1_k: int = 2,
    final_k: int = 32,
    adc_bits: int = ADC_BITS,
) -> jnp.ndarray:
    """Eq. 1: SoftMax(Top-32(QK^T)) . V through the full CAMformer datapath.

    ``q``: (d_k,) or (B, d_k); ``k``: (N, d_k); ``v``: (N, d_v).
    Contextualization runs in BF16 (Sec. III-B3); the result is returned
    as float32 holding BF16-valued numbers.
    """
    scores = bacam_scores(q, k, adc_bits)
    mask = two_stage_topk_mask(scores, group, stage1_k, final_k)
    a_hat = lut_softmax(scores, mask, q.shape[-1])
    out = a_hat.astype(jnp.bfloat16) @ v.astype(jnp.bfloat16)
    return out.astype(jnp.float32)


def single_stage_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    final_k: int = 32,
    adc_bits: int = ADC_BITS,
) -> jnp.ndarray:
    """HAD-style single-stage Top-k binary attention (Tables III/IV baseline)."""
    scores = bacam_scores(q, k, adc_bits)
    mask = single_stage_topk_mask(scores, final_k)
    a_hat = lut_softmax(scores, mask, q.shape[-1])
    out = a_hat.astype(jnp.bfloat16) @ v.astype(jnp.bfloat16)
    return out.astype(jnp.float32)


def exact_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Dense FP32 softmax attention (the un-accelerated oracle)."""
    d_k = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d_k, q.dtype))
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    a = e / jnp.sum(e, axis=-1, keepdims=True)
    return a @ v
