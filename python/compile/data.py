"""Synthetic associative-retrieval corpus for the end-to-end experiment.

The task is *exactly* the paper's Fig. 1 metaphor: the model must use a
query to "unlock" the stored value behind a matching key.

Each token either encodes a (key, value) pair or a probe:

    pair token  id = 2 + key * n_classes + value     (key stores value)
    probe token id = 2 + n_keys * n_classes + key    (asks: value of key?)

A sequence is ``seq_len - 1`` pair tokens whose keys are *distractors*
(all keys != k*), plus one target pair (k*, v*) at a random position; the
final token is the probe for k*. The label is v*. Solving the task requires
content-based retrieval: the probe's query must match the target pair's key
among hundreds of distractors — a sharp probe of whether CAMformer's
binarised, two-stage-top-k attention preserves associative recall.

This replaces ImageNet/GLUE (DESIGN.md substitution table): Tables III/IV
measure only the accuracy *delta* between attention modes, which this
corpus measures end-to-end on a really-trained model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

N_KEYS = 16
N_CLASSES = 4
PAIR_BASE = 2  # ids 0/1 reserved
PROBE_BASE = PAIR_BASE + N_KEYS * N_CLASSES
VOCAB = PROBE_BASE + N_KEYS  # = 82


def pair_token(key: jnp.ndarray, value: jnp.ndarray) -> jnp.ndarray:
    return PAIR_BASE + key * N_CLASSES + value


def probe_token(key: jnp.ndarray) -> jnp.ndarray:
    return PROBE_BASE + key


def make_batch(
    rng_key: jax.Array, batch: int, seq_len: int, vocab: int = VOCAB, n_classes: int = N_CLASSES
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sample (tokens (B,S) int32, labels (B,) int32).

    ``vocab``/``n_classes`` are accepted for signature compatibility but the
    corpus layout is fixed by the module constants.
    """
    assert n_classes == N_CLASSES
    k1, k2, k3, k4, k5 = jax.random.split(rng_key, 5)
    # target key and value per row
    kstar = jax.random.randint(k1, (batch,), 0, N_KEYS)
    vstar = jax.random.randint(k2, (batch,), 0, N_CLASSES)
    # distractor pairs: keys uniform over the *other* 15 keys
    raw = jax.random.randint(k3, (batch, seq_len - 1), 0, N_KEYS - 1)
    dk = jnp.where(raw >= kstar[:, None], raw + 1, raw)  # skip k*
    dv = jax.random.randint(k4, (batch, seq_len - 1), 0, N_CLASSES)
    toks = pair_token(dk, dv)
    # plant the target pair at a random position in [0, seq_len-1)
    pos = jax.random.randint(k5, (batch,), 0, seq_len - 1)
    rows = jnp.arange(batch)
    toks = toks.at[rows, pos].set(pair_token(kstar, vstar))
    # probe goes last
    toks = jnp.concatenate([toks, probe_token(kstar)[:, None]], axis=1)
    return toks.astype(jnp.int32), vstar.astype(jnp.int32)


def make_eval_set(
    rng_key: jax.Array, n: int, batch: int, seq_len: int, vocab: int = VOCAB, n_classes: int = N_CLASSES
):
    """A fixed held-out evaluation set as a list of batches."""
    keys = jax.random.split(rng_key, n)
    return [make_batch(k, batch, seq_len, vocab, n_classes) for k in keys]
