"""AOT lowering: JAX/Pallas -> HLO *text* -> ``artifacts/`` for Rust PJRT.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Entry points lowered (see artifacts/manifest.tsv):

  attn_single_query   — the serving hot path: one query vs the full K/V
                        memory through the Pallas BA-CAM kernel + Eq. 1.
  attn_batch          — 16-query batch of the same (coordinator batching).
  bacam_scores        — association stage only (quickstart / debugging).
  classifier_camformer— trained tiny transformer, CAMformer attention,
                        weights baked as HLO constants.
  classifier_exact    — same weights, exact attention (accuracy reference).

Run:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train
from .kernels import ba_cam

SEQ_LEN = 1024  # BERT-Large sequence length used throughout the paper
D_K = 64
BATCH = 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants`` matters: the default printer elides big
    constants as ``{...}``, which would silently drop baked model weights
    from the classifier artifacts.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax's printer emits source_end_line/column metadata that the 0.5.1
    # HLO text parser rejects — drop metadata entirely (it is debug-only)
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_entry_points(params, cfg: model.ModelConfig):
    """Return {name: (hlo_text, input_specs, output_shape_desc)}."""
    out = {}

    def attn_single(q, k, v):
        return (model.attn_single_query(q, k, v, use_pallas=True),)

    lowered = jax.jit(attn_single).lower(
        _spec((D_K,)), _spec((SEQ_LEN, D_K)), _spec((SEQ_LEN, D_K))
    )
    out["attn_single_query"] = (
        to_hlo_text(lowered),
        [f"f32[{D_K}]", f"f32[{SEQ_LEN},{D_K}]", f"f32[{SEQ_LEN},{D_K}]"],
        f"f32[{D_K}]",
    )

    def attn_batch(q, k, v):
        return (ba_cam.camformer_attention_pallas(q, k, v),)

    lowered = jax.jit(attn_batch).lower(
        _spec((BATCH, D_K)), _spec((SEQ_LEN, D_K)), _spec((SEQ_LEN, D_K))
    )
    out["attn_batch"] = (
        to_hlo_text(lowered),
        [f"f32[{BATCH},{D_K}]", f"f32[{SEQ_LEN},{D_K}]", f"f32[{SEQ_LEN},{D_K}]"],
        f"f32[{BATCH},{D_K}]",
    )

    def scores_only(q, k):
        return (ba_cam.bacam_scores_pallas(q, k, query_block=1),)

    lowered = jax.jit(scores_only).lower(_spec((1, D_K)), _spec((SEQ_LEN, D_K)))
    out["bacam_scores"] = (
        to_hlo_text(lowered),
        [f"f32[1,{D_K}]", f"f32[{SEQ_LEN},{D_K}]"],
        f"f32[1,{SEQ_LEN}]",
    )

    # Classifier variants: weights are closed over, so they lower to HLO
    # constants and the Rust side only feeds token ids.
    # Table III analogue needs first-stage k in {1,2,4,8} plus the
    # single-stage HAD baseline and the exact-attention oracle.
    variants = [
        ("classifier_camformer", "camformer", cfg.stage1_k),
        ("classifier_exact", "exact", cfg.stage1_k),
        ("classifier_single_stage", "single_stage", cfg.stage1_k),
        ("classifier_cam_k1", "camformer", 1),
        ("classifier_cam_k2", "camformer", 2),
        ("classifier_cam_k4", "camformer", 4),
        ("classifier_cam_k8", "camformer", 8),
    ]
    for name, mode, k1 in variants:
        ccfg = model.ModelConfig(
            seq_len=cfg.seq_len, attention=mode,
            group=cfg.group, stage1_k=k1, final_k=cfg.final_k,
        )

        def clf(tokens, _ccfg=ccfg):
            return (model.forward(_ccfg, params, tokens),)

        lowered = jax.jit(clf).lower(_spec((cfg.seq_len,), jnp.int32))
        out[name] = (
            to_hlo_text(lowered),
            [f"s32[{cfg.seq_len}]"],
            f"f32[{ccfg.n_classes}]",
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=300)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model.ModelConfig(seq_len=512, attention="exact")
    params_path = os.path.join(args.out, "params.npz")
    if os.path.exists(params_path):
        print(f"loading trained weights from {params_path}")
        flat = dict(np.load(params_path))
        params = train.unflatten_params(flat)
    else:
        print("no trained weights found — training the tiny transformer now")
        params, history = train.train_curriculum(
            cfg, stages=None, batch=32
        )
        np.savez(params_path, **train.flatten_params(params))
        with open(os.path.join(args.out, "train_log.tsv"), "w") as f:
            f.write("step\tloss\teval_acc\n")
            for step, loss, acc in history:
                f.write(f"{step}\t{loss:.6f}\t{acc:.4f}\n")

    entries = lower_entry_points(params, cfg)
    manifest_lines = ["name\tfile\tinputs\toutput"]
    for name, (text, in_specs, out_spec) in entries.items():
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest_lines.append(f"{name}\t{fname}\t{';'.join(in_specs)}\t{out_spec}")
        print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(entries)} entry points")


if __name__ == "__main__":
    main()
