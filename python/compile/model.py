"""Layer 2 — JAX model definitions built on the BA-CAM kernel.

A small-but-real transformer encoder whose attention can run in three modes:

* ``exact``        — dense FP32 softmax attention (the oracle),
* ``single_stage`` — HAD-style binarised Q/K + global Top-k (the paper's
                     accuracy baseline in Tables III/IV),
* ``camformer``    — Eq. 1: BA-CAM scores (Pallas kernel) + hierarchical
                     two-stage top-k + LUT softmax + BF16 contextualization.

This is the model the end-to-end example trains, the accuracy tables sweep,
and ``aot.py`` lowers to HLO text for the Rust runtime.  Python never runs
on the request path: everything here exists only at compile time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ba_cam, ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters for the tiny CAMformer-attention transformer."""

    vocab: int = 82  # data.VOCAB for the associative-retrieval corpus
    seq_len: int = 512
    d_model: int = 64
    n_heads: int = 1  # d_k = d_model / n_heads; CAM-friendly d_k = 64
    n_layers: int = 2
    d_ff: int = 128
    n_classes: int = 4
    attention: str = "exact"  # exact | single_stage | camformer
    group: int = ref.CAM_H
    stage1_k: int = 2
    final_k: int = 32
    adc_bits: int = ref.ADC_BITS
    use_pallas: bool = False  # camformer scores via the Pallas kernel
    # The associative-retrieval task is position-free (content-addressable
    # by construction), so positional embeddings default off — which also
    # makes trained weights sequence-length agnostic (curriculum training).
    use_pos: bool = False

    @property
    def d_k(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, Any]:
    """Initialise all weights (Xavier-ish scaling, deterministic in key)."""
    ks = iter(jax.random.split(key, 4 + 8 * cfg.n_layers))

    def dense(kk, fan_in, fan_out):
        w = jax.random.normal(kk, (fan_in, fan_out), jnp.float32)
        return w * (2.0 / (fan_in + fan_out)) ** 0.5

    params: dict[str, Any] = {
        "embed": jax.random.normal(next(ks), (cfg.vocab, cfg.d_model)) * 0.02,
        "pos": jax.random.normal(next(ks), (cfg.seq_len, cfg.d_model)) * 0.02,
        "head_w": dense(next(ks), cfg.d_model, cfg.n_classes),
        "head_b": jnp.zeros((cfg.n_classes,)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "wq": dense(next(ks), cfg.d_model, cfg.d_model),
                "wk": dense(next(ks), cfg.d_model, cfg.d_model),
                "wv": dense(next(ks), cfg.d_model, cfg.d_model),
                "wo": dense(next(ks), cfg.d_model, cfg.d_model),
                "w1": dense(next(ks), cfg.d_model, cfg.d_ff),
                "b1": jnp.zeros((cfg.d_ff,)),
                "w2": dense(next(ks), cfg.d_ff, cfg.d_model),
                "b2": jnp.zeros((cfg.d_model,)),
                "ln1_g": jnp.ones((cfg.d_model,)),
                "ln1_b": jnp.zeros((cfg.d_model,)),
                "ln2_g": jnp.ones((cfg.d_model,)),
                "ln2_b": jnp.zeros((cfg.d_model,)),
            }
        )
    return params


def _layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def ste_binarize(x: jnp.ndarray) -> jnp.ndarray:
    """Straight-through sign binarisation: forward = sign(x), backward =
    identity — the HAD training trick that makes Q/K binarisation
    learnable."""
    b = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    return x + jax.lax.stop_gradient(b - x)


def binary_ste_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, final_k: int
) -> jnp.ndarray:
    """Differentiable binarised top-k attention for HAD-style fine-tuning.

    Forward numerics match the single-stage CAMformer path at d_k <= 64
    (exact ADC); gradients flow through the STE and the kept scores."""
    d_k = q.shape[-1]
    qb = ste_binarize(q)
    kb = ste_binarize(k)
    scores = qb @ kb.T
    # threshold-based top-k (argsort-rank masks hit a jax gather-batching
    # limitation under grad+vmap); ties may admit a few extra keys, which
    # is harmless for training
    kth = jax.lax.stop_gradient(jax.lax.top_k(scores, final_k)[0][..., -1:])
    mask = scores >= kth
    x = jnp.where(mask, scores / jnp.sqrt(jnp.asarray(d_k, q.dtype)), -jnp.inf)
    a = jax.nn.softmax(x, axis=-1)
    a = jnp.where(mask, a, 0.0)
    return a @ v


def attention(cfg: ModelConfig, q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Single-head attention dispatch over (S, d_k) tensors."""
    if cfg.attention == "exact":
        return ref.exact_attention(q, k, v)
    if cfg.attention == "binary_ste":
        return binary_ste_attention(q, k, v, cfg.final_k)
    if cfg.attention == "single_stage":
        return ref.single_stage_attention(q, k, v, cfg.final_k, cfg.adc_bits)
    if cfg.attention == "camformer":
        if cfg.use_pallas:
            return ba_cam.camformer_attention_pallas(
                q, k, v, cfg.group, cfg.stage1_k, cfg.final_k, cfg.adc_bits
            )
        return ref.camformer_attention(
            q, k, v, cfg.group, cfg.stage1_k, cfg.final_k, cfg.adc_bits
        )
    raise ValueError(f"unknown attention mode {cfg.attention!r}")


def mha(cfg: ModelConfig, lp: dict[str, Any], x: jnp.ndarray) -> jnp.ndarray:
    """Multi-head attention over (S, d_model) with the configured score path."""
    s, d = x.shape
    h, dk = cfg.n_heads, cfg.d_k
    q = (x @ lp["wq"]).reshape(s, h, dk)
    k = (x @ lp["wk"]).reshape(s, h, dk)
    v = (x @ lp["wv"]).reshape(s, h, dk)
    outs = [attention(cfg, q[:, i, :], k[:, i, :], v[:, i, :]) for i in range(h)]
    o = jnp.concatenate([o.reshape(s, dk) for o in outs], axis=-1)
    return o @ lp["wo"]


def encoder_layer(cfg: ModelConfig, lp: dict[str, Any], x: jnp.ndarray) -> jnp.ndarray:
    """Pre-LN transformer block: x + MHA(LN(x)); x + FF(LN(x))."""
    a = mha(cfg, lp, _layer_norm(x, lp["ln1_g"], lp["ln1_b"]))
    x = x + a
    hdn = jax.nn.gelu(_layer_norm(x, lp["ln2_g"], lp["ln2_b"]) @ lp["w1"] + lp["b1"])
    return x + hdn @ lp["w2"] + lp["b2"]


def forward(cfg: ModelConfig, params: dict[str, Any], tokens: jnp.ndarray) -> jnp.ndarray:
    """Token ids (S,) int32 -> class logits (n_classes,)."""
    x = params["embed"][tokens]
    if cfg.use_pos:
        x = x + params["pos"][: tokens.shape[0]]
    for lp in params["layers"]:
        x = encoder_layer(cfg, lp, x)
    # readout at the probe position (the last token asks the question —
    # Fig. 1's "query unlocks the stored value")
    pooled = x[-1]
    return pooled @ params["head_w"] + params["head_b"]


def forward_batch(cfg: ModelConfig, params: dict[str, Any], tokens: jnp.ndarray) -> jnp.ndarray:
    """(B, S) int32 -> (B, n_classes)."""
    return jax.vmap(lambda t: forward(cfg, params, t))(tokens)


@functools.partial(jax.jit, static_argnums=0)
def loss_fn(cfg: ModelConfig, params, tokens, labels) -> jnp.ndarray:
    logits = forward_batch(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def attn_single_query(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    group: int = ref.CAM_H,
    stage1_k: int = 2,
    final_k: int = 32,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """The serving hot path lowered for the Rust coordinator: one query
    against the full key/value memory (batch = 1, Sec. III-B1)."""
    fn = ba_cam.camformer_attention_pallas if use_pallas else ref.camformer_attention
    return fn(q, k, v, group, stage1_k, final_k)
