"""Emit golden vectors for the Rust functional model.

The jnp oracle (ref.py) computes BA-CAM scores and full CAMformer attention
for seeded random inputs; the Rust side (`rust/tests/golden_vectors.rs`)
re-computes them with `accuracy::functional` and asserts agreement —
scores bit-exact, attention within bf16 slack.

Run:  cd python && python -m compile.golden --out ../artifacts/golden.tsv
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref


def emit_case(f, case_id: int, n: int, seed: int) -> None:
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (64,), jnp.float32)
    k = jax.random.normal(kk, (n, 64), jnp.float32)
    v = jax.random.normal(kv, (n, 64), jnp.float32)

    scores = ref.bacam_scores(q, k)
    out = ref.camformer_attention(q, k, v)

    def fmt(arr):
        return ",".join(f"{float(x):.9g}" for x in np.asarray(arr).ravel())

    f.write(f"case\t{case_id}\t{n}\n")
    f.write(f"q\t{fmt(q)}\n")
    f.write(f"k\t{fmt(k)}\n")
    f.write(f"v\t{fmt(v)}\n")
    f.write(f"scores\t{fmt(scores)}\n")
    f.write(f"attention\t{fmt(out)}\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/golden.tsv")
    args = ap.parse_args()
    with open(args.out, "w") as f:
        for case_id, (n, seed) in enumerate([(64, 1), (128, 2), (256, 3), (512, 4), (1024, 5)]):
            emit_case(f, case_id, n, seed)
    print(f"wrote golden vectors to {args.out}")


if __name__ == "__main__":
    main()
