"""Train the tiny associative-retrieval transformer (build time only).

Trains with *exact* attention, then the accuracy harness (Tables III/IV
analogue) re-evaluates the same weights under single-stage and two-stage
CAMformer attention — the post-training-binarisation protocol HAD uses,
minus the distillation fine-tune we cannot afford at build time.

Run as a module:  cd python && python -m compile.train --out ../artifacts

Artifacts written:
  params.npz      — trained weights (flat {path: array})
  train_log.tsv   — step, loss, eval accuracy (the loss curve for
                    EXPERIMENTS.md's end-to-end validation record)
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model


def flatten_params(params, prefix=""):
    """dict/list tree -> flat {dotted.path: np.ndarray}."""
    out = {}
    if isinstance(params, dict):
        items = params.items()
    elif isinstance(params, list):
        items = ((str(i), v) for i, v in enumerate(params))
    else:
        return {prefix.rstrip("."): np.asarray(params)}
    for name, v in items:
        out.update(flatten_params(v, f"{prefix}{name}."))
    return out


def unflatten_params(flat: dict) -> dict:
    """Inverse of :func:`flatten_params` (lists detected by integer keys)."""
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(v)

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.isdigit() for k in node):
            return [fix(node[str(i)]) for i in range(len(node))]
        return {k: fix(v) for k, v in node.items()}

    return fix(tree)


def evaluate(cfg, params, eval_set) -> float:
    correct = total = 0
    for toks, labels in eval_set:
        logits = model.forward_batch(cfg, params, toks)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == labels))
        total += labels.shape[0]
    return correct / total


def adam_step(params, grads, state, lr: float, clip: float = 1.0,
              b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    """Adam with global-norm gradient clipping (hand-rolled; no optax dep).

    ``state`` is {"t": int, "m": {path: arr}, "v": {path: arr}}.
    """
    flat_p = flatten_params(params)
    flat_g = {k: np.asarray(v, dtype=np.float64) for k, v in flatten_params(grads).items()}
    gnorm = float(np.sqrt(sum((g**2).sum() for g in flat_g.values())))
    scale = min(1.0, clip / max(gnorm, 1e-12))
    t = state.get("t", 0) + 1
    m, v = state.get("m", {}), state.get("v", {})
    new_p = {}
    for k, g in flat_g.items():
        g = g * scale
        m[k] = b1 * m.get(k, 0.0) + (1 - b1) * g
        v[k] = b2 * v.get(k, 0.0) + (1 - b2) * g * g
        mhat = m[k] / (1 - b1**t)
        vhat = v[k] / (1 - b2**t)
        new_p[k] = np.asarray(flat_p[k]) - lr * mhat / (np.sqrt(vhat) + eps)
    return unflatten_params({k: v.astype(np.float32) for k, v in new_p.items()}), {
        "t": t, "m": m, "v": v,
    }


def train(
    cfg: model.ModelConfig,
    steps: int = 300,
    batch: int = 16,
    lr: float = 1e-3,
    seed: int = 0,
    log=print,
    params=None,
    step_offset: int = 0,
):
    """Train and return (params, [(step, loss, acc)...]).

    ``params`` continues training from existing weights (curriculum)."""
    key = jax.random.PRNGKey(seed)
    pkey, dkey, ekey = jax.random.split(key, 3)
    if params is None:
        params = model.init_params(cfg, pkey)
    eval_set = data.make_eval_set(ekey, 8, 32, cfg.seq_len, cfg.vocab, cfg.n_classes)

    grad_fn = jax.jit(
        jax.value_and_grad(lambda p, t, l: model.loss_fn(cfg, p, t, l)),
    )
    opt_state: dict = {}
    history = []
    t0 = time.time()
    for step in range(1, steps + 1):
        dkey, bkey = jax.random.split(dkey)
        toks, labels = data.make_batch(bkey, batch, cfg.seq_len, cfg.vocab, cfg.n_classes)
        loss, grads = grad_fn(params, toks, labels)
        params, opt_state = adam_step(params, grads, opt_state, lr)
        if step % 25 == 0 or step == 1:
            acc = evaluate(cfg, params, eval_set)
            history.append((step + step_offset, float(loss), acc))
            log(f"step {step + step_offset:4d}  loss {float(loss):.4f}  eval_acc {acc:.3f}  ({time.time()-t0:.0f}s)")
    return params, history


def train_curriculum(
    cfg: model.ModelConfig,
    stages: list[tuple[int, int]] | None = None,
    batch: int = 32,
    lr: float = 1e-3,
    seed: int = 0,
    log=print,
):
    """Curriculum: the position-free model trains fast on short sequences,
    then fine-tunes at the target length. Returns (params, history)."""
    assert not cfg.use_pos, "curriculum requires the position-free model"
    if stages is None:
        # exact-attention pretraining, then HAD-style binarisation-aware
        # fine-tuning (STE) so binary top-k attention retains accuracy
        stages = [
            (64, 400, "exact"),
            (128, 200, "exact"),
            (128, 300, "binary_ste"),
            (cfg.seq_len, 150, "binary_ste"),
        ]
    params, history = None, []
    offset = 0
    for stage in stages:
        seq_len, steps = stage[0], stage[1]
        mode = stage[2] if len(stage) > 2 else cfg.attention
        stage_cfg = dataclasses.replace(cfg, seq_len=seq_len, attention=mode)
        log(f"-- curriculum stage: seq_len={seq_len}, steps={steps}, attention={mode} --")
        params, h = train(
            stage_cfg, steps=steps, batch=batch, lr=lr, seed=seed,
            log=log, params=params, step_offset=offset,
        )
        history.extend(h)
        offset += steps
    return params, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = model.ModelConfig(seq_len=args.seq_len, attention="exact")
    os.makedirs(args.out, exist_ok=True)
    params, history = train_curriculum(
        cfg,
        stages=None,
        batch=32,
        seed=args.seed,
    )

    flat = flatten_params(params)
    np.savez(os.path.join(args.out, "params.npz"), **flat)
    with open(os.path.join(args.out, "train_log.tsv"), "w") as f:
        f.write("step\tloss\teval_acc\n")
        for step, loss, acc in history:
            f.write(f"{step}\t{loss:.6f}\t{acc:.4f}\n")
    print(f"saved {len(flat)} tensors to {args.out}/params.npz")


if __name__ == "__main__":
    main()
