"""Oracle invariants for the pure-jnp reference datapath (ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


def randn(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


class TestBinarize:
    def test_values_are_pm_one(self):
        x = randn((32, 64))
        b = ref.binarize(x)
        assert bool(jnp.all((b == 1.0) | (b == -1.0)))

    def test_zero_maps_to_plus_one(self):
        assert float(ref.binarize(jnp.zeros((1,)))[0]) == 1.0

    def test_idempotent(self):
        x = randn((16, 16), 1)
        b = ref.binarize(x)
        assert bool(jnp.all(ref.binarize(b) == b))


class TestMatchlineVoltage:
    def test_range(self):
        q = ref.binarize(randn((64,), 2))
        k = ref.binarize(randn((128, 64), 3))
        v = ref.matchline_voltage(q, k)
        assert bool(jnp.all((v >= 0) & (v <= 1)))

    def test_full_match_is_one(self):
        q = ref.binarize(randn((64,), 4))
        v = ref.matchline_voltage(q, q[None, :])
        assert float(v[0]) == 1.0

    def test_full_mismatch_is_zero(self):
        q = ref.binarize(randn((64,), 5))
        v = ref.matchline_voltage(q, -q[None, :])
        assert float(v[0]) == 0.0

    def test_single_bit_flip_steps_by_one_over_dk(self):
        q = ref.binarize(randn((64,), 6))
        k = q.at[3].set(-q[3])[None, :]
        v = ref.matchline_voltage(q, k)
        np.testing.assert_allclose(float(v[0]), 63 / 64, rtol=1e-6)


class TestAdcQuantize:
    def test_exact_for_dk64_6bit(self):
        # 6-bit SAR covers the full match range at d_k=64 (Sec. III-B1):
        # every possible match count maps to itself.
        for matches in range(65):
            v = jnp.asarray(matches / 64.0)
            s = ref.adc_quantize(v, 64, 6)
            assert float(s) == 2 * matches - 64

    def test_score_range(self):
        v = jnp.linspace(0, 1, 101)
        s = ref.adc_quantize(v, 64, 6)
        assert bool(jnp.all((s >= -64) & (s <= 64)))

    def test_monotone(self):
        v = jnp.linspace(0, 1, 1001)
        s = np.asarray(ref.adc_quantize(v, 64, 6))
        assert (np.diff(s) >= 0).all()

    @pytest.mark.parametrize("bits", [4, 5, 6, 8])
    def test_quantization_error_bound(self, bits):
        # |error| <= half an LSB of the match range
        v = jnp.linspace(0, 1, 777)
        s = ref.adc_quantize(v, 64, bits)
        ideal = 2 * (v * 64) - 64
        lsb = 2 * 64 / 2**bits
        assert float(jnp.max(jnp.abs(s - ideal))) <= lsb / 2 + 1e-5


class TestBacamScores:
    def test_matches_integer_dot_for_dk64(self):
        q = randn((8, 64), 7)
        k = randn((256, 64), 8)
        s = ref.bacam_scores(q, k)
        exact = ref.binarize(q) @ ref.binarize(k).T
        np.testing.assert_array_equal(np.asarray(s), np.asarray(exact))

    def test_tiled_equals_flat_when_dk_eq_camw(self):
        q, k = randn((4, 64), 9), randn((32, 64), 10)
        np.testing.assert_array_equal(
            np.asarray(ref.bacam_scores(q, k)),
            np.asarray(ref.bacam_scores_tiled(q, k)),
        )

    def test_tiled_dk128_exact(self):
        # per-tile 6-bit ADC at CAM_W=64 is lossless, so the tiled sum is
        # the exact binary dot product even for d_k=128
        q, k = randn((4, 128), 11), randn((64, 128), 12)
        s = ref.bacam_scores_tiled(q, k)
        exact = ref.binarize(q) @ ref.binarize(k).T
        np.testing.assert_array_equal(np.asarray(s), np.asarray(exact))

    def test_noise_changes_scores(self):
        q, k = randn((2, 64), 13), randn((64, 64), 14)
        s0 = ref.bacam_scores(q, k)
        s1 = ref.bacam_scores(q, k, noise_sigma=0.05, noise_key=jax.random.PRNGKey(0))
        assert not bool(jnp.all(s0 == s1))
        assert bool(jnp.all(jnp.abs(s1) <= 64))


class TestTwoStageTopK:
    def test_mask_count(self):
        s = ref.bacam_scores(randn((1024, 64), 15)[:1], randn((1024, 64), 16))
        m = ref.two_stage_topk_mask(s, 16, 2, 32)
        assert int(jnp.sum(m)) == 32

    def test_all_survive_when_candidates_le_final(self):
        # N/group*stage1_k <= final_k: stage 2 keeps every candidate
        s = ref.bacam_scores(randn((1, 64), 17), randn((128, 64), 18))
        m = ref.two_stage_topk_mask(s, 16, 2, 32)
        assert int(jnp.sum(m)) == 16  # 8 tiles * top-2

    def test_single_stage_recovered_with_group_eq_n(self):
        s = ref.bacam_scores(randn((1, 64), 19), randn((256, 64), 20))
        two = ref.two_stage_topk_mask(s, group=256, stage1_k=32, final_k=32)
        one = ref.single_stage_topk_mask(s, 32)
        np.testing.assert_array_equal(np.asarray(two), np.asarray(one))

    def test_stage1_keeps_per_tile_top(self):
        s = jnp.arange(64.0)[None, :]  # strictly increasing
        m = ref.two_stage_topk_mask(s, group=16, stage1_k=2, final_k=4)
        # per-tile top-2 = indices 14,15 / 30,31 / 46,47 / 62,63; global top-4
        kept = set(np.where(np.asarray(m)[0])[0].tolist())
        assert kept == {62, 63, 46, 47}

    def test_kept_entries_dominate_dropped_within_tile(self):
        s = ref.bacam_scores(randn((1, 64), 21), randn((512, 64), 22))
        m = np.asarray(ref.two_stage_topk_mask(s, 16, 2, 32))[0]
        sv = np.asarray(s)[0]
        for t in range(512 // 16):
            tile = slice(16 * t, 16 * (t + 1))
            kept = sv[tile][m[tile]]
            dropped = sv[tile][~m[tile]]
            if kept.size and dropped.size:
                assert kept.min() >= dropped.max() - 1e-6


class TestLutSoftmax:
    def test_probabilities(self):
        s = ref.bacam_scores(randn((4, 64), 23), randn((256, 64), 24))
        m = ref.two_stage_topk_mask(s)
        a = ref.lut_softmax(s, m, 64)
        np.testing.assert_allclose(np.asarray(jnp.sum(a, -1)), 1.0, rtol=1e-5)
        assert bool(jnp.all(a >= 0))
        assert bool(jnp.all(jnp.where(m, True, a == 0)))

    def test_uniform_when_scores_equal(self):
        s = jnp.full((1, 64), 10.0)
        m = ref.single_stage_topk_mask(s, 8)
        a = ref.lut_softmax(s, m, 64)
        np.testing.assert_allclose(np.asarray(a[m]), 1 / 8, rtol=1e-5)


class TestEndToEnd:
    def test_output_shape(self):
        q, k, v = randn((64,), 25), randn((256, 64), 26), randn((256, 64), 27)
        out = ref.camformer_attention(q, k, v)
        assert out.shape == (64,)

    def test_batched(self):
        q, k, v = randn((8, 64), 28), randn((256, 64), 29), randn((256, 64), 30)
        out = ref.camformer_attention(q, k, v)
        assert out.shape == (8, 64)

    def test_convex_combination_bound(self):
        # output is a convex combination of V rows (bf16 rounding slack)
        q, k, v = randn((64,), 31), randn((256, 64), 32), randn((256, 64), 33)
        out = ref.camformer_attention(q, k, v)
        assert float(jnp.max(out)) <= float(jnp.max(v)) + 0.05
        assert float(jnp.min(out)) >= float(jnp.min(v)) - 0.05

    def test_two_stage_close_to_single_stage(self):
        # k1=2, g=16 keeps Tables III/IV deltas small; outputs should agree
        # on most coordinates for generic gaussian data
        q, k, v = randn((16, 64), 34), randn((1024, 64), 35), randn((1024, 64), 36)
        two = ref.camformer_attention(q, k, v)
        one = ref.single_stage_attention(q, k, v)
        # cosine similarity per row
        num = jnp.sum(two * one, -1)
        den = jnp.linalg.norm(two, axis=-1) * jnp.linalg.norm(one, axis=-1)
        assert float(jnp.min(num / den)) > 0.75

    def test_exact_attention_is_softmax(self):
        q, k, v = randn((4, 16), 37), randn((32, 16), 38), randn((32, 8), 39)
        out = ref.exact_attention(q, k, v)
        # reference softmax computed independently
        a = jax.nn.softmax((q @ k.T) / jnp.sqrt(16.0), axis=-1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ v), rtol=1e-5)
