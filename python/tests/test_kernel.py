"""Pallas BA-CAM kernel vs the pure-jnp oracle — the core L1 signal.

hypothesis sweeps shapes/dtypes per the repo testing policy; every sweep
asserts bit-exact (scores) or allclose (attention) agreement with ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ba_cam, ref


def randn(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


class TestScoresParity:
    @pytest.mark.parametrize("n", [16, 64, 256, 1024])
    @pytest.mark.parametrize("b", [1, 8])
    def test_bit_exact_dk64(self, n, b):
        q, k = randn((b, 64), n + b), randn((n, 64), n + b + 1)
        s_ref = ref.bacam_scores(q, k)
        s_pal = ba_cam.bacam_scores_pallas(q, k, query_block=min(8, b))
        np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))

    @pytest.mark.parametrize("dk", [64, 128, 256])
    def test_vertical_tiling_matches_tiled_ref(self, dk):
        q, k = randn((4, dk), dk), randn((64, dk), dk + 1)
        s_ref = ref.bacam_scores_tiled(q, k)
        s_pal = ba_cam.bacam_scores_pallas(q, k, query_block=4)
        np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))

    @pytest.mark.parametrize("adc_bits", [4, 5, 6, 8])
    def test_adc_bits_parity(self, adc_bits):
        q, k = randn((2, 64), adc_bits), randn((128, 64), adc_bits + 1)
        s_ref = ref.bacam_scores(q, k, adc_bits=adc_bits)
        s_pal = ba_cam.bacam_scores_pallas(q, k, adc_bits=adc_bits, query_block=2)
        np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4, 8]),
        n_tiles=st.integers(1, 16),
        d_tiles=st.integers(1, 3),
        seed=st.integers(0, 2**20),
    )
    def test_hypothesis_shape_sweep(self, b, n_tiles, d_tiles, seed):
        n, dk = 16 * n_tiles, 64 * d_tiles
        q = randn((b, dk), seed)
        k = randn((n, dk), seed + 1)
        s_ref = ref.bacam_scores_tiled(q, k)
        s_pal = ba_cam.bacam_scores_pallas(q, k, query_block=b)
        np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**20))
    def test_hypothesis_binary_inputs(self, seed):
        # already-binary inputs are a fixed point of in-kernel binarisation
        q = ref.binarize(randn((2, 64), seed))
        k = ref.binarize(randn((64, 64), seed + 1))
        s = ba_cam.bacam_scores_pallas(q, k, query_block=2)
        np.testing.assert_array_equal(np.asarray(s), np.asarray(q @ k.T))

    def test_dtype_bfloat16_inputs(self):
        q = randn((2, 64), 40).astype(jnp.bfloat16).astype(jnp.float32)
        k = randn((64, 64), 41).astype(jnp.bfloat16).astype(jnp.float32)
        s = ba_cam.bacam_scores_pallas(q, k, query_block=2)
        np.testing.assert_array_equal(
            np.asarray(s), np.asarray(ref.bacam_scores(q, k))
        )


class TestPaddedWrapper:
    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 200),
        dk=st.sampled_from([16, 32, 48, 64, 96, 128]),
        seed=st.integers(0, 2**20),
    )
    def test_shape_and_range(self, n, dk, seed):
        q, k = randn((1, dk), seed), randn((n, dk), seed + 1)
        s = ba_cam.bacam_scores_padded(q, k)
        assert s.shape == (1, n)
        assert bool(jnp.all(jnp.abs(s) <= dk))

    def test_no_padding_needed_is_exact(self):
        q, k = randn((8, 64), 42), randn((128, 64), 43)
        np.testing.assert_array_equal(
            np.asarray(ba_cam.bacam_scores_padded(q, k)),
            np.asarray(ref.bacam_scores(q, k)),
        )

    def test_padded_ordering_preserved(self):
        # the physical-array ADC grid may differ from the idealised ref by
        # up to one code, but must preserve score *ordering* (what top-k
        # consumes)
        q, k = randn((1, 48), 44), randn((50, 48), 45)
        s_pad = np.asarray(ba_cam.bacam_scores_padded(q, k))[0]
        exact = np.asarray(ref.binarize(q) @ ref.binarize(k).T)[0]
        # identical exact scores may permute, so compare grouped ordering
        assert (s_pad[np.argsort(exact)] == np.sort(s_pad)).all()


class TestAttentionParity:
    @pytest.mark.parametrize("n", [128, 512, 1024])
    def test_end_to_end_allclose(self, n):
        q, k, v = randn((4, 64), n), randn((n, 64), n + 1), randn((n, 64), n + 2)
        o_ref = ref.camformer_attention(q, k, v)
        o_pal = ba_cam.camformer_attention_pallas(q, k, v)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal), atol=1e-5)

    def test_single_query_shape(self):
        q, k, v = randn((64,), 50), randn((256, 64), 51), randn((256, 64), 52)
        out = ba_cam.camformer_attention_pallas(q, k, v)
        assert out.shape == (64,)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.camformer_attention(q, k, v)), atol=1e-5
        )

    @settings(max_examples=10, deadline=None)
    @given(
        stage1_k=st.sampled_from([1, 2, 4, 8]),
        final_k=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**20),
    )
    def test_hypothesis_topk_configs(self, stage1_k, final_k, seed):
        q, k, v = randn((2, 64), seed), randn((512, 64), seed + 1), randn((512, 64), seed + 2)
        o_ref = ref.camformer_attention(q, k, v, 16, stage1_k, final_k)
        o_pal = ba_cam.camformer_attention_pallas(q, k, v, 16, stage1_k, final_k)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal), atol=1e-5)
