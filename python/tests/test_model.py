"""L2 model tests: shapes, attention-mode dispatch, the synthetic corpus,
and a short real optimisation run (loss must drop)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model, train
from compile.kernels import ref


CFG = model.ModelConfig(seq_len=128, attention="exact")


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


class TestForward:
    def test_logits_shape(self, params):
        toks = jnp.zeros((CFG.seq_len,), jnp.int32)
        assert model.forward(CFG, params, toks).shape == (CFG.n_classes,)

    def test_batch_shape(self, params):
        toks = jnp.zeros((3, CFG.seq_len), jnp.int32)
        assert model.forward_batch(CFG, params, toks).shape == (3, CFG.n_classes)

    def test_finite(self, params):
        toks, _ = data.make_batch(jax.random.PRNGKey(1), 2, CFG.seq_len, CFG.vocab, 4)
        logits = model.forward_batch(CFG, params, toks)
        assert bool(jnp.all(jnp.isfinite(logits)))

    @pytest.mark.parametrize("mode", ["exact", "single_stage", "camformer", "binary_ste"])
    def test_attention_modes_run(self, params, mode):
        cfg = model.ModelConfig(seq_len=128, attention=mode)
        toks = jnp.zeros((128,), jnp.int32)
        logits = model.forward(cfg, params, toks)
        assert logits.shape == (4,)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_camformer_pallas_matches_ref_path(self, params):
        cfg_r = model.ModelConfig(seq_len=128, attention="camformer", use_pallas=False)
        cfg_p = model.ModelConfig(seq_len=128, attention="camformer", use_pallas=True)
        toks, _ = data.make_batch(jax.random.PRNGKey(2), 1, 128, CFG.vocab, 4)
        lr = model.forward(cfg_r, params, toks[0])
        lp = model.forward(cfg_p, params, toks[0])
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lp), atol=1e-4)

    def test_attention_dispatch_rejects_unknown(self, params):
        cfg = model.ModelConfig(seq_len=128, attention="nope")
        with pytest.raises(ValueError):
            model.attention(cfg, jnp.zeros((4, 64)), jnp.zeros((4, 64)), jnp.zeros((4, 64)))


class TestMhaStructure:
    def test_multi_head_splits_dk(self):
        cfg = model.ModelConfig(seq_len=128, d_model=64, n_heads=2)
        assert cfg.d_k == 32
        p = model.init_params(cfg, jax.random.PRNGKey(3))
        x = jax.random.normal(jax.random.PRNGKey(4), (128, 64))
        out = model.mha(cfg, p["layers"][0], x)
        assert out.shape == (128, 64)

    def test_camformer_attention_uses_topk(self):
        # with final_k = N the camformer path degenerates toward binary
        # softmax attention; with tiny final_k outputs must differ
        q = jax.random.normal(jax.random.PRNGKey(5), (8, 64))
        k = jax.random.normal(jax.random.PRNGKey(6), (128, 64))
        v = jax.random.normal(jax.random.PRNGKey(7), (128, 64))
        wide = ref.camformer_attention(q, k, v, 16, 16, 128)
        narrow = ref.camformer_attention(q, k, v, 16, 1, 4)
        assert not bool(jnp.allclose(wide, narrow, atol=1e-3))


class TestSteBinarization:
    def test_forward_is_sign(self):
        x = jnp.asarray([-2.0, -0.1, 0.0, 0.5, 3.0])
        b = model.ste_binarize(x)
        np.testing.assert_array_equal(np.asarray(b), [-1.0, -1.0, 1.0, 1.0, 1.0])

    def test_backward_is_identity(self):
        g = jax.grad(lambda x: jnp.sum(model.ste_binarize(x) * 3.0))(
            jnp.asarray([0.5, -0.5])
        )
        np.testing.assert_allclose(np.asarray(g), [3.0, 3.0])

    def test_binary_ste_tracks_single_stage_forward(self):
        # the STE training path (threshold mask, plain softmax, f32 matmul)
        # and the inference single-stage path (rank mask, LUT softmax, bf16)
        # differ at score *ties* on the top-k boundary, so compare by
        # correlation rather than elementwise equality
        q = jax.random.normal(jax.random.PRNGKey(20), (4, 64))
        k = jax.random.normal(jax.random.PRNGKey(21), (128, 64))
        v = jax.random.normal(jax.random.PRNGKey(22), (128, 64))
        ste = np.asarray(model.binary_ste_attention(q, k, v, 32)).ravel()
        ref_out = np.asarray(ref.single_stage_attention(q, k, v, 32)).ravel()
        r = np.corrcoef(ste, ref_out)[0, 1]
        assert r > 0.97, f"correlation {r}"

    def test_gradients_flow_through_binary_attention(self):
        cfg = model.ModelConfig(seq_len=64, d_model=32, n_layers=1, d_ff=64,
                                attention="binary_ste")
        p = model.init_params(cfg, jax.random.PRNGKey(23))
        toks, labels = data.make_batch(jax.random.PRNGKey(24), 4, 64, cfg.vocab, 4)
        grads = jax.grad(lambda pp: model.loss_fn(cfg, pp, toks, labels))(p)
        flat = train.flatten_params(grads)
        assert np.abs(flat["layers.0.wq"]).sum() > 0
        assert np.abs(flat["layers.0.wk"]).sum() > 0


class TestData:
    def test_probe_is_last_and_valid(self):
        toks, _ = data.make_batch(jax.random.PRNGKey(8), 64, 256)
        toks = np.asarray(toks)
        probes = toks[:, -1]
        assert (probes >= data.PROBE_BASE).all()
        assert (probes < data.PROBE_BASE + data.N_KEYS).all()
        # pair tokens only before the probe
        assert (toks[:, :-1] >= data.PAIR_BASE).all()
        assert (toks[:, :-1] < data.PROBE_BASE).all()

    def test_target_pair_unique_and_label_consistent(self):
        toks, labels = data.make_batch(jax.random.PRNGKey(9), 32, 128)
        toks, labels = np.asarray(toks), np.asarray(labels)
        for row in range(32):
            kstar = toks[row, -1] - data.PROBE_BASE
            keys = (toks[row, :-1] - data.PAIR_BASE) // data.N_CLASSES
            vals = (toks[row, :-1] - data.PAIR_BASE) % data.N_CLASSES
            hits = np.where(keys == kstar)[0]
            assert len(hits) == 1, "target key must appear exactly once"
            assert vals[hits[0]] == labels[row]

    def test_labels_balanced_ish(self):
        _, labels = data.make_batch(jax.random.PRNGKey(10), 512, 128)
        counts = np.bincount(np.asarray(labels), minlength=4)
        assert counts.min() > 512 / 4 * 0.5

    def test_vocab_constant_consistent(self):
        assert data.VOCAB == data.PROBE_BASE + data.N_KEYS
        assert model.ModelConfig().vocab == data.VOCAB

    def test_eval_set_deterministic(self):
        a = data.make_eval_set(jax.random.PRNGKey(11), 2, 4, 64)
        b = data.make_eval_set(jax.random.PRNGKey(11), 2, 4, 64)
        assert bool(jnp.all(a[0][0] == b[0][0]))


class TestTraining:
    def test_loss_decreases(self):
        cfg = model.ModelConfig(seq_len=64, d_model=32, n_layers=1, d_ff=64)
        params, history = train.train(cfg, steps=150, batch=16, lr=2e-3, log=lambda *a: None)
        first_loss = history[0][1]
        # single-batch losses are noisy: average the recorded tail
        tail = [h[1] for h in history[-3:]]
        assert sum(tail) / len(tail) < first_loss, f"{history}"

    def test_flatten_unflatten_roundtrip(self):
        cfg = model.ModelConfig(seq_len=64, d_model=32, n_layers=2, d_ff=64)
        p = model.init_params(cfg, jax.random.PRNGKey(12))
        flat = train.flatten_params(p)
        p2 = train.unflatten_params(flat)
        toks = jnp.zeros((64,), jnp.int32)
        np.testing.assert_allclose(
            np.asarray(model.forward(cfg, p, toks)),
            np.asarray(model.forward(cfg, p2, toks)),
            rtol=1e-6,
        )

    def test_gradients_flow_everywhere(self):
        cfg = model.ModelConfig(seq_len=64, d_model=32, n_layers=1, d_ff=64)
        p = model.init_params(cfg, jax.random.PRNGKey(13))
        toks, labels = data.make_batch(jax.random.PRNGKey(14), 4, 64, cfg.vocab, 4)
        grads = jax.grad(lambda pp: model.loss_fn(cfg, pp, toks, labels))(p)
        flat = train.flatten_params(grads)
        # embeddings, attention and head must all receive grads (pos is
        # excluded: the position-free model never reads it)
        for name in ["embed", "head_w", "layers.0.wq", "layers.0.w2"]:
            assert np.abs(flat[name]).sum() > 0, name
