"""AOT lowering tests: HLO text generation, constant baking, manifest."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ba_cam


class TestToHloText:
    def test_small_function_lowers(self):
        lowered = jax.jit(lambda x: (x * 2.0,)).lower(
            jax.ShapeDtypeStruct((4,), jnp.float32)
        )
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_large_constants_not_elided(self):
        w = jnp.arange(4096.0).reshape(64, 64)
        lowered = jax.jit(lambda x: (x @ w,)).lower(
            jax.ShapeDtypeStruct((2, 64), jnp.float32)
        )
        text = aot.to_hlo_text(lowered)
        # the default printer writes {...}; ours must keep the payload
        assert "constant({...})" not in text.replace(" ", "")
        assert "4095" in text  # last element of the weight matrix

    def test_metadata_stripped(self):
        # xla_extension 0.5.1's parser rejects source_end_line metadata
        lowered = jax.jit(lambda x: (x + 1.0,)).lower(
            jax.ShapeDtypeStruct((4,), jnp.float32)
        )
        text = aot.to_hlo_text(lowered)
        assert "source_end_line" not in text
        assert "metadata=" not in text

    def test_pallas_kernel_lowers_to_plain_hlo(self):
        def fn(q, k):
            return (ba_cam.bacam_scores_pallas(q, k, query_block=1),)

        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((1, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
        )
        text = aot.to_hlo_text(lowered)
        # interpret=True means no Mosaic custom-call survives lowering
        assert "custom-call" not in text or "mosaic" not in text.lower()


class TestEntryPoints:
    @pytest.fixture(scope="class")
    def entries(self):
        cfg = model.ModelConfig(seq_len=128, attention="exact")
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        return aot.lower_entry_points(params, cfg)

    def test_all_entry_points_present(self, entries):
        names = set(entries)
        assert {
            "attn_single_query",
            "attn_batch",
            "bacam_scores",
            "classifier_camformer",
            "classifier_exact",
            "classifier_single_stage",
            "classifier_cam_k1",
            "classifier_cam_k2",
            "classifier_cam_k4",
            "classifier_cam_k8",
        } <= names

    def test_specs_are_wellformed(self, entries):
        for name, (text, inputs, output) in entries.items():
            assert "HloModule" in text, name
            for spec in inputs + [output]:
                assert "[" in spec and spec.endswith("]"), f"{name}: {spec}"
