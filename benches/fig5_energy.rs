//! Bench: BIMV engine throughput across the Fig. 5 amortisation sweep,
//! plus the bit-sliced int paths.

use camformer::bimv::bitslice;
use camformer::bimv::engine::BimvEngine;
use camformer::camcircuit::energy::EnergyModel;
use camformer::util::bench::Bencher;
use camformer::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(3);
    let q: Vec<bool> = (0..64).map(|_| rng.bool()).collect();

    for n in [64usize, 256, 1024] {
        let keys: Vec<Vec<bool>> = (0..n)
            .map(|_| (0..64).map(|_| rng.bool()).collect())
            .collect();
        let mut eng = BimvEngine::new(16, 64);
        b.bench(&format!("bimv_scores_n{n}"), || eng.scores(&q, &keys));
    }

    let vals: Vec<Vec<u32>> = (0..64)
        .map(|_| (0..64).map(|_| rng.range(0, 256) as u32).collect())
        .collect();
    let mut eng = BimvEngine::new(16, 64);
    b.bench("bitslice_int8_n64", || {
        bitslice::bimv_int(&mut eng, &q, &vals, 8)
    });

    // the analytic energy sweep itself (cheap, but part of fig5 regen)
    let model = EnergyModel::new(16, 64);
    b.bench("fig5_energy_sweep", || model.fig5_sweep(14));

    println!("\n-- modelled energy (not wall time) --");
    for (m, fj) in model.fig5_sweep(14) {
        println!("M={m:6}  {fj:.1} fJ/op");
    }
    print!("{}", b.summary());
}
