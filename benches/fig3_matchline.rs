//! Bench: the analog circuit substrate's hot paths (Fig. 3 regeneration
//! cost) — matchline settle, full-array search, PVT Monte-Carlo point.

use camformer::camcircuit::array::BaCamArray;
use camformer::camcircuit::cell::CellParams;
use camformer::camcircuit::matchline::Matchline;
use camformer::camcircuit::pvt::{self, Corner, PvtConfig};
use camformer::util::bench::Bencher;
use camformer::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let params = CellParams::default();
    let mut rng = Rng::new(1);

    let bits: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
    let ml = Matchline::new(&bits, &params);
    let query: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
    b.bench("matchline_settled_voltage_64", || {
        ml.settled_voltage(&query, &params)
    });
    b.bench("matchline_transient_64", || {
        ml.transient(&query, &params, 0.5)
    });

    let keys: Vec<Vec<bool>> = (0..16)
        .map(|_| (0..64).map(|_| rng.bool()).collect())
        .collect();
    let mut arr = BaCamArray::new(16, 64);
    arr.program(&keys);
    b.bench("array_search_16x64", || arr.search(&query));

    let mut arr_pvt = BaCamArray::with_pvt(16, 64, Corner::SS, 0.014, 9);
    arr_pvt.program(&keys);
    b.bench("array_search_16x64_pvt", || arr_pvt.search(&query));

    let mut prng = Rng::new(2);
    b.bench("pvt_point_200_trials", || {
        pvt::pvt_point(
            &PvtConfig { corner: Corner::TT, mismatch_sigma: 0.014, trials: 200 },
            64,
            32,
            &mut prng,
        )
    });

    print!("{}", b.summary());
}
