//! Bench: trace-driven traffic + energy/latency co-simulation
//! (ISSUE 10) — generated workloads replayed through a live server with
//! every dispatch priced through the circuit models. Each scenario
//! reports the co-simulation quartet — tokens/s, p99 latency, J/token,
//! average power — and the set is emitted machine-readably to
//! `BENCH_serving.json` so tools/check_bench.py can gate the energy
//! accounting (keys present, J/token finite and nonzero, fused cheaper
//! than dense) across PRs:
//!
//!   bert_steady   — the BERT-class serving mix on an uncontended server:
//!                   the headline throughput/energy operating point;
//!   vit_bursty    — the ViT-class mix slammed through a queue bounded at
//!                   4: constant overload sheds, all replayed to
//!                   completion by the driver's closed retry loop;
//!   zipf_spill    — the Zipf-hotset mix on a 2-shard server with two
//!                   resident sessions per worker: the session tail
//!                   churns through the DRAM spill tier, so the energy
//!                   total carries a live DRAM share;
//!   longctx_fused — one session at n ≈ 1024 decoded through the fused
//!   longctx_dense   FlashCAM kernel vs the dense-mask baseline: the
//!                   paper's energy claim at serving scale (the dense
//!                   pipeline contextualizes every row, the fused kernel
//!                   streams tiles and touches ≤ k survivors).

use std::time::Duration;

use camformer::coordinator::{
    CamformerServer, FunctionalBackend, Metrics, ReclaimPolicy, ServerConfig,
};
use camformer::workload::{generate, EnergyAccountant, Trace, TraceSpec, TrafficDriver};

/// The co-simulation quartet for one scenario.
struct Row {
    tokens_per_s: f64,
    p99_ms: f64,
    j_per_token: f64,
    watts: f64,
}

/// Replay `trace` against `server` at full speed and price the run:
/// asserts the closed retry loop landed every scheduled token, then
/// folds the accumulated work counters into joules.
fn price(label: &str, spec: &TraceSpec, trace: &Trace, server: CamformerServer) -> (Row, Metrics) {
    let report = TrafficDriver::full_speed().replay(trace, &server).unwrap();
    assert!(report.completed(), "{label}: {} ops never resolved", report.failed);
    assert_eq!(report.decoded_tokens, trace.decode_ops() as u64, "{label}: lost tokens");
    let (mut metrics, window) = server.shutdown();
    let acct = EnergyAccountant::paper(spec.d_v);
    acct.attach(&mut metrics);
    let row = Row {
        tokens_per_s: report.tokens_per_s(),
        p99_ms: report.p99_us() / 1e3,
        j_per_token: metrics.energy_per_token_j(),
        watts: metrics.watts(window),
    };
    assert!(
        row.j_per_token.is_finite() && row.j_per_token > 0.0,
        "{label}: energy accounting must price every run ({})",
        row.j_per_token
    );
    println!(
        "bench serving_{label:<14} {:>9.0} tok/s  p99 {:>8.2} ms  {:>10.3e} J/tok  {:>8.3e} W",
        row.tokens_per_s, row.p99_ms, row.j_per_token, row.watts
    );
    println!("      {label}: {}", metrics.summary(window));
    (row, metrics)
}

/// Long-context single-session spec: one session decoding over an
/// n ≈ 1024 cache — the shape where the fused-vs-dense energy gap is
/// widest (the bench's ISSUE-7 companion at serving scale).
fn longctx_spec() -> TraceSpec {
    TraceSpec {
        label: "longctx",
        requests: 256,
        population: 1,
        zipf_s: 0.0,
        rate_per_s: 2000.0,
        prefill_rows: (960, 960),
        decode_steps: (64, 64),
        d_k: 64,
        d_v: 64,
    }
}

fn main() {
    let mut rows: Vec<(&'static str, Row)> = Vec::new();

    // scenario: BERT-class steady state — provisioned capacity, default
    // policy, no contention: the clean operating point
    {
        let spec = TraceSpec::bert();
        let trace = generate(&spec, 1);
        let cap = spec.kv_capacity();
        let server = CamformerServer::start(
            ServerConfig { kv_capacity: cap, d_k: spec.d_k, d_v: spec.d_v, ..Default::default() },
            move |_| FunctionalBackend::new(cap, 64),
        );
        let (row, _) = price("bert_steady", &spec, &trace, server);
        rows.push(("bert_steady", row));
    }

    // scenario: ViT-class burst through a queue bounded at 4 — the shed
    // path must stay on the priced hot path (every shed is replayed)
    {
        let spec = TraceSpec::vit();
        let trace = generate(&spec, 2);
        let cap = spec.kv_capacity();
        let server = CamformerServer::start(
            ServerConfig {
                kv_capacity: cap,
                max_queue: 4,
                d_k: spec.d_k,
                d_v: spec.d_v,
                ..Default::default()
            },
            move |_| FunctionalBackend::new(cap, 64),
        );
        let (row, m) = price("vit_bursty", &spec, &trace, server);
        assert!(m.shed_requests > 0, "full-speed replay must overrun max_queue = 4");
        rows.push(("vit_bursty", row));
    }

    // scenario: Zipf hotset on a 2-shard server with a 2-session
    // resident tier — the spill tier churns, so the DRAM channel model
    // contributes a live share of the energy total
    {
        let spec = TraceSpec::zipf_hotset();
        let trace = generate(&spec, 3);
        let cap = spec.kv_capacity();
        let server = CamformerServer::start(
            ServerConfig {
                shards: 2,
                kv_capacity: cap,
                max_sessions: 2,
                reclaim: ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
                d_k: spec.d_k,
                d_v: spec.d_v,
                ..Default::default()
            },
            move |_| FunctionalBackend::new(cap, 64),
        );
        let (row, m) = price("zipf_spill", &spec, &trace, server);
        assert!(m.demotions > 0 && m.promotions > 0, "hotset must churn the spill tier");
        assert!(m.dram_energy_j > 0.0, "spill churn must charge DRAM energy");
        rows.push(("zipf_spill", row));
    }

    // scenario pair: long-context decode, fused FlashCAM kernel vs the
    // dense-mask baseline over the SAME trace — the serving-scale energy
    // comparison check_bench.py gates (fused must stay cheaper per token)
    {
        let spec = longctx_spec();
        let trace = generate(&spec, 4);
        let cap = spec.kv_capacity();
        let cfg = ServerConfig {
            kv_capacity: cap,
            max_sessions: 1,
            d_k: spec.d_k,
            d_v: spec.d_v,
            ..Default::default()
        };
        let fused = CamformerServer::start(cfg.clone(), move |_| FunctionalBackend::new(cap, 64));
        let (row_f, _) = price("longctx_fused", &spec, &trace, fused);
        let dense = CamformerServer::start(cfg, move |_| FunctionalBackend::new_dense(cap, 64));
        let (row_d, _) = price("longctx_dense", &spec, &trace, dense);
        assert!(
            row_f.j_per_token < row_d.j_per_token,
            "fused kernel must decode cheaper than the dense baseline \
             ({:.3e} vs {:.3e} J/token)",
            row_f.j_per_token,
            row_d.j_per_token
        );
        rows.push(("longctx_fused", row_f));
        rows.push(("longctx_dense", row_d));
    }

    // machine-readable co-simulation surface (scenario -> quartet),
    // gated by tools/check_bench.py across PRs
    let mut json = String::from("{\n");
    for (i, (name, r)) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        json.push_str(&format!(
            "  \"{name}\": {{\"tokens_per_s\": {:.1}, \"p99_ms\": {:.3}, \
             \"j_per_token\": {:.6e}, \"watts\": {:.6e}}}{sep}\n",
            r.tokens_per_s, r.p99_ms, r.j_per_token, r.watts
        ));
    }
    json.push_str("}\n");
    std::fs::write("BENCH_serving.json", &json).expect("write BENCH_serving.json");
    println!("      wrote BENCH_serving.json ({} scenarios)", rows.len());
}
