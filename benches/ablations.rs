//! Ablation benches (DESIGN.md ablation list): two-stage vs single-stage
//! top-k, ADC precision, CAM geometry, batch=1 vs batch=16, recall cost.

use camformer::accuracy::functional;
use camformer::accuracy::recall;
use camformer::arch::config::ArchConfig;
use camformer::arch::pipeline::PipelineModel;
use camformer::runtime::executable::default_artifacts_dir;
use camformer::runtime::executable::Engine;
use camformer::util::bench::Bencher;
use camformer::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(6);
    let scores: Vec<f64> = (0..1024).map(|_| rng.normal(0.0, 20.0)).collect();

    // ablation 1: selection network cost
    b.bench("topk_single_stage_1024", || {
        functional::single_stage_topk_mask(&scores, 32)
    });
    b.bench("topk_two_stage_1024", || {
        functional::two_stage_topk_mask(&scores, 16, 2, 32)
    });

    // ablation 2: ADC precision on the scores path
    let q = rng.normal_vec(64);
    let k = rng.normal_vec(1024 * 64);
    for bits in [4u32, 6, 8] {
        b.bench(&format!("bacam_scores_adc{bits}"), || {
            functional::bacam_scores_cfg(&q, &k, 64, bits)
        });
    }

    // ablation 3: recall cost of the hierarchy (modelled, printed below)
    println!("\n-- modelled ablations --");
    let mut r = Rng::new(7);
    for k1 in [1usize, 2, 4, 8] {
        let wr = recall::monte_carlo_weighted_recall_realistic(1024, 8, 16, k1, 32, 60, &mut r);
        println!("two-stage k1={k1}: weighted recall {wr:.4}");
    }

    // ablation 4: batching (Sec. III-B1 argues batch=1; measure the
    // software dispatch side on PJRT)
    let dir = default_artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        let v = rng.normal_vec(1024 * 64);
        let mut engine = Engine::new(&dir).expect("engine");
        engine.load("attn_single_query").unwrap();
        engine.load("attn_batch").unwrap();
        let mut bc = Bencher::coarse();
        let r1 = bc.bench("pjrt_single_query_x16", || {
            for _ in 0..16 {
                engine
                    .load("attn_single_query")
                    .unwrap()
                    .run_f32(&[&q, &k, &v])
                    .unwrap();
            }
        });
        let qs = rng.normal_vec(16 * 64);
        let r2 = bc.bench("pjrt_batch16_once", || {
            engine.load("attn_batch").unwrap().run_f32(&[&qs, &k, &v]).unwrap()
        });
        println!(
            "batch=16 speedup over 16x single (software dispatch): {:.2}x",
            r1.mean_ns / r2.mean_ns
        );
    }

    // ablation 5: hardware cadence vs CAM height (modelled)
    for cam_h in [8usize, 16, 32] {
        let cfg = ArchConfig { cam_h, ..Default::default() };
        let m = PipelineModel { cfg, fine_grained: true };
        println!(
            "CAM_H={cam_h:2}: association {} cycles, {:.1} qry/ms",
            m.latencies().association,
            m.throughput_qry_per_ms()
        );
    }
    print!("{}", b.summary());
}
