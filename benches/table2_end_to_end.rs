//! Bench: end-to-end single-query attention through each backend — the
//! software-side Table II. The modelled silicon numbers print alongside
//! for the paper comparison.

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::arch::{config::ArchConfig, pipeline};
use camformer::baselines::accelerators;
use camformer::runtime::executable::{default_artifacts_dir, Engine};
use camformer::util::bench::Bencher;
use camformer::util::rng::Rng;

fn main() {
    let mut b = Bencher::coarse();
    let mut rng = Rng::new(5);
    let q = rng.normal_vec(64);
    let k = rng.normal_vec(1024 * 64);
    let v = rng.normal_vec(1024 * 64);

    let cfg = AttnConfig::paper(1024, 64);
    b.bench("functional_model_n1024", || {
        functional::camformer_attention(&q, &k, &v, &cfg)
    });

    // §Perf before/after, measured live each run:
    //   float reference (iter 0) -> branchless u8 count (iter 2)
    //   -> pre-packed XNOR+popcount for reused keys (iter 3)
    b.bench("scores_iter0_float_n1024", || {
        functional::bacam_scores_float_reference(&q, &k, 64, 6)
    });
    b.bench("scores_iter2_branchless_n1024", || {
        functional::bacam_scores_cfg(&q, &k, 64, 6)
    });
    let packed = functional::PackedKeys::new(&k, 64);
    b.bench("scores_iter3_prepacked_n1024", || packed.scores(&q, 6));
    b.bench("attention_prepacked_n1024", || {
        functional::camformer_attention_packed(&q, &packed, &v, &cfg)
    });

    b.bench("exact_attention_n1024", || {
        functional::exact_attention(&q, &k, &v, 1024, 64)
    });

    let arch_cfg = ArchConfig::default();
    b.bench("arch_simulator_n1024", || {
        pipeline::simulate_query(arch_cfg, &q, &k, &v)
    });

    let dir = default_artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        let mut engine = Engine::new(&dir).expect("engine");
        engine.load("attn_single_query").expect("load");
        b.bench("pjrt_attn_single_query", || {
            engine
                .load("attn_single_query")
                .unwrap()
                .run_f32(&[&q, &k, &v])
                .unwrap()
        });

        let qs = rng.normal_vec(16 * 64);
        engine.load("attn_batch").expect("load");
        b.bench("pjrt_attn_batch16", || {
            engine.load("attn_batch").unwrap().run_f32(&[&qs, &k, &v]).unwrap()
        });
    } else {
        println!("(skipping PJRT benches: run `make artifacts`)");
    }

    println!("\n-- modelled silicon (Table II) --");
    for r in accelerators::table2_rows() {
        println!(
            "{:22} {:>8.1} qry/ms {:>8.0} qry/mJ {:>8} mm^2 {:>6.2} W",
            r.name,
            r.throughput_qry_per_ms,
            r.energy_eff_qry_per_mj,
            r.area_mm2.map(|a| format!("{a:.2}")).unwrap_or("-".into()),
            r.power_w
        );
    }
    print!("{}", b.summary());
}
