//! Bench: per-stage functional simulation cost + the modelled hardware
//! throughput table (Fig. 9 regeneration).

use camformer::arch::association::AssociationStage;
use camformer::arch::bitonic::{self, Entry};
use camformer::arch::config::ArchConfig;
use camformer::arch::contextualization::ContextualizationStage;
use camformer::arch::normalization::NormalizationStage;
use camformer::arch::pipeline::PipelineModel;
use camformer::util::bench::Bencher;
use camformer::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let cfg = ArchConfig::default();
    let mut rng = Rng::new(4);

    let q: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
    let keys: Vec<Vec<bool>> = (0..1024)
        .map(|_| (0..64).map(|_| rng.bool()).collect())
        .collect();
    let mut assoc = AssociationStage::new(cfg);
    let assoc_out = assoc.run(&q, &keys);
    b.bench("association_stage_n1024", || assoc.run(&q, &keys));

    let norm = NormalizationStage::new(cfg);
    let norm_out = norm.run(&assoc_out.candidates);
    b.bench("normalization_stage_128cand", || {
        norm.run(&assoc_out.candidates)
    });

    let v: Vec<f32> = rng.normal_vec(1024 * 64);
    let ctx = ContextualizationStage::new(cfg);
    b.bench("contextualization_stage_k32", || {
        ctx.run(&norm_out.selected, &norm_out.probs, &v)
    });

    let entries: Vec<Entry> = (0..64)
        .map(|i| Entry { score: rng.normal(0.0, 10.0), index: i })
        .collect();
    b.bench("bitonic_sort_64", || {
        let mut d = entries.clone();
        bitonic::bitonic_sort(&mut d)
    });

    println!("\n-- modelled hardware throughput (cycles @ 1 GHz) --");
    for (fine, label) in [(false, "no fine pipelining"), (true, "fine-grained")] {
        let m = PipelineModel { cfg, fine_grained: fine };
        let l = m.latencies();
        println!(
            "{label:20} assoc={:6} norm={:5} ctx={:5}  pipeline {:.1} qry/ms",
            l.association,
            l.normalization,
            l.contextualization,
            m.throughput_qry_per_ms()
        );
    }
    print!("{}", b.summary());
}
