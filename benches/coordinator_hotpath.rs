//! Bench: the Layer-3 serving hot path — prefill/decode/attend round
//! trips through the session-oriented coordinator, the cross-session
//! batched decode loop (batched vs single dispatch), the long-context
//! dense-vs-sparse-vs-fused / repack-vs-incremental comparison
//! (ISSUEs 4, 7, emitted machine-readably to `BENCH_hotpath.json`), the
//! bursty open-loop
//! arrival scenario against the standing scheduler's bounded queue and
//! shared KV budget (ISSUE 6), the spill-tier churn scenario where an
//! over-subscribed resident tier demotes/promotes KV through the
//! modeled host DRAM (ISSUE 8), the chaos-restart scenario that prices
//! serving straight through periodic worker crashes — supervised
//! respawn, lost-session re-opens, spill-tier recovery (ISSUE 9) —
//! plus the micro-costs (bf16 dot, softmax engine) that dominate it.

use std::time::{Duration, Instant};

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::arch::softmax::SoftmaxEngine;
use camformer::coordinator::backend::{
    AttendItem, AttentionBackend, ChaosBackend, Fault, FaultPlan, FunctionalBackend,
};
use camformer::coordinator::batcher::{BatchPolicy, PlanMode};
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, ReclaimPolicy, Request, ServerConfig};
use camformer::coordinator::{ServeError, SessionHandle};
use camformer::util::bench::Bencher;
use camformer::util::{bf16, rng::Rng};

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(8);

    // micro: bf16 weighted-sum inner loop (the contextualization kernel)
    let a = rng.normal_vec(64);
    let v = rng.normal_vec(64);
    b.bench("bf16_dot_64", || bf16::dot(&a, &v));

    // micro: softmax engine
    let eng = SoftmaxEngine::new(64);
    let scores: Vec<f64> = (0..32).map(|_| rng.range(0, 129) as f64 - 64.0).collect();
    b.bench("softmax_engine_32", || eng.normalize(&scores));

    // macro: read-heavy serving — prefill once, stream Attends
    for (label, heads, requests) in [("1head", 1usize, 64usize), ("4heads", 4, 256)] {
        let n = 1024;
        let mut bc = Bencher::coarse();
        bc.bench(&format!("serve_attend_{label}_{requests}req"), || {
            let server = CamformerServer::start(
                ServerConfig {
                    heads,
                    kv_capacity: n,
                    batch: BatchPolicy::bounds(16, Duration::from_micros(200)),
                    ..Default::default()
                },
                |_| FunctionalBackend::new(n, 64),
            );
            let mut kv_rng = Rng::new(9);
            let mut tickets = Vec::with_capacity(requests + heads);
            for h in 0..heads {
                tickets.push(
                    server
                        .submit_ticket(Request::Prefill {
                            id: 100_000 + h as u64,
                            session: 1,
                            head: h,
                            keys: kv_rng.normal_vec(n * 64),
                            values: kv_rng.normal_vec(n * 64),
                        })
                        .unwrap(),
                );
            }
            let mut qrng = Rng::new(10);
            for i in 0..requests {
                tickets.push(
                    server
                        .submit_ticket(Request::Attend {
                            id: i as u64,
                            session: 1,
                            head: i % heads,
                            query: qrng.normal_vec(64),
                        })
                        .unwrap(),
                );
            }
            assert_eq!(tickets.len(), requests + heads);
            for t in tickets {
                assert!(t.wait().is_ok());
            }
            let (m, w) = server.shutdown();
            (m.completed, w)
        });
    }

    // macro: the decode loop — live KV append + attend per step, the
    // paper's growing-cache serving scenario (Sec. IV-C)
    for (label, sessions, steps) in [("2sess", 2usize, 64usize), ("8sess", 8, 32)] {
        let capacity = 256usize;
        let prefill_rows = 64usize;
        let mut bc = Bencher::coarse();
        bc.bench(&format!("decode_loop_{label}_{steps}steps"), || {
            let server = CamformerServer::start(
                ServerConfig {
                    kv_capacity: capacity,
                    max_sessions: sessions,
                    batch: BatchPolicy::bounds(16, Duration::from_micros(200)),
                    ..Default::default()
                },
                |_| FunctionalBackend::new(capacity, 64),
            );
            let mut rng2 = Rng::new(11);
            let mut id = 0u64;
            let mut tickets = Vec::with_capacity(sessions * (steps + 1));
            for sid in 0..sessions as u64 {
                tickets.push(
                    server
                        .submit_ticket(Request::Prefill {
                            id: 100_000 + sid,
                            session: sid,
                            head: 0,
                            keys: rng2.normal_vec(prefill_rows * 64),
                            values: rng2.normal_vec(prefill_rows * 64),
                        })
                        .unwrap(),
                );
            }
            for _step in 0..steps {
                for sid in 0..sessions as u64 {
                    tickets.push(
                        server
                            .submit_ticket(Request::Decode {
                                id,
                                session: sid,
                                head: 0,
                                query: rng2.normal_vec(64),
                                new_key: rng2.normal_vec(64),
                                new_value: rng2.normal_vec(64),
                            })
                            .unwrap(),
                    );
                    id += 1;
                }
            }
            assert_eq!(tickets.len(), sessions * (steps + 1));
            for t in tickets {
                assert!(t.wait().is_ok());
            }
            let (m, w) = server.shutdown();
            (m.decodes, w)
        });
    }

    // macro: cross-session batched decode (pinned to conservative
    // planning — the ISSUE 2 comparison). The same interleaved
    // multi-session decode stream runs once with every request
    // dispatched alone (max_batch = 1) and once through the
    // DecodeBatcher (max_batch = 16), which coalesces one step from each
    // session into a single backend dispatch (key-stationary
    // amortisation, Fig. 5). Payloads are pre-generated so the submit
    // loop is pure channel sends and batches actually fill.
    {
        let sessions = 8usize;
        let steps = 32usize;
        let capacity = 256usize;
        let prefill_rows = 64usize;
        let mut payload_rng = Rng::new(12);
        let prefills: Vec<(Vec<f32>, Vec<f32>)> = (0..sessions)
            .map(|_| {
                (
                    payload_rng.normal_vec(prefill_rows * 64),
                    payload_rng.normal_vec(prefill_rows * 64),
                )
            })
            .collect();
        // (session, query, new_key, new_value) in interleaved round-robin order
        let decodes: Vec<(u64, Vec<f32>, Vec<f32>, Vec<f32>)> = (0..steps)
            .flat_map(|_| (0..sessions as u64).collect::<Vec<_>>())
            .map(|sid| {
                (
                    sid,
                    payload_rng.normal_vec(64),
                    payload_rng.normal_vec(64),
                    payload_rng.normal_vec(64),
                )
            })
            .collect();
        for (label, max_batch) in [("single", 1usize), ("batched", 16usize)] {
            let mut bc = Bencher::coarse();
            let mut best_occupancy = 0.0f64;
            bc.bench(&format!("xsession_decode_{label}_{sessions}sess_{steps}steps"), || {
                let server = CamformerServer::start(
                    ServerConfig {
                        kv_capacity: capacity,
                        max_sessions: sessions,
                        batch: BatchPolicy::conservative(max_batch, Duration::from_millis(2)),
                        ..Default::default()
                    },
                    |_| FunctionalBackend::new(capacity, 64),
                );
                let mut tickets = Vec::with_capacity(sessions + decodes.len());
                for (sid, (keys, values)) in prefills.iter().enumerate() {
                    tickets.push(
                        server
                            .submit_ticket(Request::Prefill {
                                id: 100_000 + sid as u64,
                                session: sid as u64,
                                head: 0,
                                keys: keys.clone(),
                                values: values.clone(),
                            })
                            .unwrap(),
                    );
                }
                for (id, (sid, q, nk, nv)) in decodes.iter().enumerate() {
                    tickets.push(
                        server
                            .submit_ticket(Request::Decode {
                                id: id as u64,
                                session: *sid,
                                head: 0,
                                query: q.clone(),
                                new_key: nk.clone(),
                                new_value: nv.clone(),
                            })
                            .unwrap(),
                    );
                }
                assert_eq!(tickets.len(), sessions + decodes.len());
                for t in tickets {
                    assert!(t.wait().is_ok());
                }
                let (m, w) = server.shutdown();
                best_occupancy = best_occupancy.max(m.mean_occupancy());
                (m.decodes, w)
            });
            println!(
                "      xsession_decode_{label}: batch occupancy {best_occupancy:.2}x \
                 (queries per backend dispatch, best iteration)"
            );
            // best-of-iterations, not last: a single preempted iteration
            // must not make the self-check flaky
            if max_batch > 1 {
                assert!(
                    best_occupancy > 1.0,
                    "interleaved-session decode must amortise dispatches \
                     (occupancy {best_occupancy:.2}x)"
                );
            }
        }
    }

    // macro: speculative multi-step fusion (ISSUE 3) — a deep
    // single-session decode burst, the dominant decode-serving shape.
    // Conservative planning flushes at every step of the burst and
    // degrades to occupancy 1; speculative fusion packs many steps of
    // the one session into each dispatch (each attending over its own
    // causal prefix view) and must exceed occupancy 1. Bit-equality of
    // the two modes is proven by rust/tests/batcher_fuzz.rs, not here.
    {
        let steps = 64usize;
        let capacity = 256usize;
        let prefill_rows = 64usize;
        let mut payload_rng = Rng::new(13);
        let prefill = (
            payload_rng.normal_vec(prefill_rows * 64),
            payload_rng.normal_vec(prefill_rows * 64),
        );
        let decodes: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..steps)
            .map(|_| {
                let q = payload_rng.normal_vec(64);
                let nk = payload_rng.normal_vec(64);
                let nv = payload_rng.normal_vec(64);
                (q, nk, nv)
            })
            .collect();
        let modes = [("conservative", PlanMode::Conservative), ("fused", PlanMode::Speculative)];
        for (label, mode) in modes {
            let batch = BatchPolicy {
                max_batch: 16,
                max_wait: Duration::from_millis(2),
                mode,
                ..Default::default()
            };
            let mut bc = Bencher::coarse();
            let mut best_occupancy = 0.0f64;
            bc.bench(&format!("deep_burst_{label}_1sess_{steps}steps"), || {
                let server = CamformerServer::start(
                    ServerConfig {
                        kv_capacity: capacity,
                        max_sessions: 1,
                        batch,
                        ..Default::default()
                    },
                    |_| FunctionalBackend::new(capacity, 64),
                );
                let mut tickets = Vec::with_capacity(steps + 1);
                tickets.push(
                    server
                        .submit_ticket(Request::Prefill {
                            id: 100_000,
                            session: 0,
                            head: 0,
                            keys: prefill.0.clone(),
                            values: prefill.1.clone(),
                        })
                        .unwrap(),
                );
                for (id, (q, nk, nv)) in decodes.iter().enumerate() {
                    tickets.push(
                        server
                            .submit_ticket(Request::Decode {
                                id: id as u64,
                                session: 0,
                                head: 0,
                                query: q.clone(),
                                new_key: nk.clone(),
                                new_value: nv.clone(),
                            })
                            .unwrap(),
                    );
                }
                assert_eq!(tickets.len(), steps + 1);
                for t in tickets {
                    assert!(t.wait().is_ok());
                }
                let (m, w) = server.shutdown();
                best_occupancy = best_occupancy.max(m.mean_occupancy());
                (m.decodes, w)
            });
            println!(
                "      deep_burst_{label}: batch occupancy {best_occupancy:.2}x \
                 (queries per backend dispatch, best iteration)"
            );
            match mode {
                PlanMode::Speculative => assert!(
                    best_occupancy > 1.0,
                    "deep single-session burst must fuse multiple steps per dispatch \
                     (occupancy {best_occupancy:.2}x)"
                ),
                PlanMode::Conservative => assert!(
                    (best_occupancy - 1.0).abs() < 1e-9,
                    "conservative planning serves a deep burst one step per dispatch \
                     (occupancy {best_occupancy:.2}x)"
                ),
            }
        }
    }

    // macro: session lifecycle churn (ISSUE 5) — a 16-session population
    // served through a worker capped at max_sessions = 4 under
    // LruEvictIdle: every over-limit `open` must evict the LRU idle
    // session instead of failing terminally (previously SessionLimit),
    // half the handles close explicitly, and the lifecycle counters
    // (evictions, closes, KV rows released) must come back non-zero.
    {
        let capacity = 128usize;
        let max_sessions = 4usize;
        let population = 16usize;
        let steps_per_session = 4usize;
        let mut bc = Bencher::coarse();
        let mut last = (0u64, 0u64, 0u64);
        bc.bench("session_churn_lru_16sess_cap4", || {
            let server = CamformerServer::start(
                ServerConfig {
                    kv_capacity: capacity,
                    max_sessions,
                    reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
                    batch: BatchPolicy::bounds(16, Duration::from_micros(200)),
                    ..Default::default()
                },
                |_| FunctionalBackend::new(capacity, 64),
            );
            let mut rng2 = Rng::new(14);
            let mut served = 0u64;
            // keep the odd handles alive so capacity pressure is
            // resolved by the reclaim policy, not by our closes
            let mut resident: Vec<SessionHandle<'_>> = Vec::new();
            for sid in 0..population as u64 {
                let h = server
                    .open(sid, rng2.normal_vec(16 * 64), rng2.normal_vec(16 * 64))
                    .expect("LruEvictIdle must admit by evicting the LRU idle session");
                let tickets: Vec<_> = (0..steps_per_session)
                    .map(|_| {
                        h.decode(rng2.normal_vec(64), rng2.normal_vec(64), rng2.normal_vec(64))
                            .unwrap()
                    })
                    .collect();
                for t in tickets {
                    assert!(t.wait().is_ok(), "churn decode failed");
                    served += 1;
                }
                if sid % 2 == 0 {
                    h.close().unwrap();
                } else {
                    resident.push(h);
                }
            }
            drop(resident);
            let (m, w) = server.shutdown();
            assert!(m.evictions > 0, "over-subscribed opens must evict");
            assert!(m.closes > 0, "explicit closes must be counted");
            assert!(m.kv_rows_released > 0, "lifecycle must release KV capacity");
            last = (m.evictions, m.closes, m.kv_rows_released);
            (served, w)
        });
        println!(
            "      session_churn: evictions={} closes={} kv_rows_released={} \
             (16 opens through a 4-session worker)",
            last.0, last.1, last.2
        );
    }

    // macro: long-context single-session decode (ISSUEs 4, 7) — the
    // asymptotic comparison behind the survivor-list sparse pipeline,
    // incremental key packing, and the fused FlashCAM kernel. Four
    // per-step recipes over the same growing KV cache:
    //   dense_full_repack  — the pre-ISSUE-4 hot path: re-pack the whole
    //                        padded buffer after every append (what
    //                        on_kv_update + the identity cache forced),
    //                        then walk all rows through the dense mask
    //                        pipeline: O(n·d) per step, twice over;
    //   dense_incremental  — store-owned bits (append packs ONE row) but
    //                        dense softmax/contextualization: O(n·d);
    //   sparse_incremental — the ISSUE-4 hot path: store-owned bits +
    //                        survivor-list pipeline: O(n + k·d) per step;
    //   fused_incremental  — the serving default since ISSUE 7: one
    //                        streaming pass over 16-row key tiles, u64
    //                        XOR+popcount word scoring, a running top-k
    //                        threshold carried tile to tile — no
    //                        materialized n-length score vector at all:
    //                        O(n·d/64 + k·d) per step with a word-level
    //                        constant.
    // All four are asserted bit-identical step by step, and the work
    // counters pin the asymptotics exactly: sparse/fused
    // contextualization touches ≤ final_k V rows per step, every append
    // packs exactly one row, and the fused kernel scores precisely one
    // u64 word per live row (d = 64) while streaming ceil(len/16) tiles.
    let mut hotpath_json: Vec<(String, f64)> = Vec::new();
    {
        let d = 64usize;
        let quantum = 16usize;
        for steps in [256usize, 1024, 4096] {
            let mut payload_rng = Rng::new(20 + steps as u64);
            let decodes: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..steps)
                .map(|_| {
                    (
                        payload_rng.normal_vec(d),
                        payload_rng.normal_vec(d),
                        payload_rng.normal_vec(d),
                    )
                })
                .collect();

            // (a) dense contextualization + full re-pack per step
            let mut dense_outs: Vec<Vec<f32>> = Vec::with_capacity(steps);
            let mut store = KvStore::new(steps, d, d);
            let t0 = Instant::now();
            for (q, nk, nv) in &decodes {
                store.append(nk, nv).unwrap();
                let rows = store.len().div_ceil(quantum) * quantum;
                let (kp, vp, valid) = store.padded(rows);
                let packed = functional::PackedKeys::new(kp, d); // O(n·d) re-pack
                let cfg = AttnConfig::paper(rows, d);
                let out =
                    functional::camformer_attention_packed_prefix(q, &packed, vp, &cfg, valid);
                dense_outs.push(out);
            }
            let ns_dense = t0.elapsed().as_nanos() as f64 / steps as f64;

            // (b) dense contextualization over store-owned incremental bits
            let mut dense_inc_outs: Vec<Vec<f32>> = Vec::with_capacity(steps);
            let mut store = KvStore::new(steps, d, d);
            let mut backend = FunctionalBackend::new_dense(steps, d);
            let t0 = Instant::now();
            for (q, nk, nv) in &decodes {
                store.append(nk, nv).unwrap();
                let rows = store.len().div_ceil(quantum) * quantum;
                let (kp, vp, valid) = store.padded(rows);
                let item = AttendItem {
                    query: q,
                    keys: kp,
                    values: vp,
                    prefix_rows: valid,
                    packed: Some(store.packed_view(rows)),
                };
                dense_inc_outs.push(backend.attend_batch(&[item]).unwrap().remove(0));
            }
            let ns_dense_inc = t0.elapsed().as_nanos() as f64 / steps as f64;

            // (c) the ISSUE-4 hot path: sparse pipeline + incremental bits
            let mut sparse_outs: Vec<Vec<f32>> = Vec::with_capacity(steps);
            let mut store = KvStore::new(steps, d, d);
            let mut backend = FunctionalBackend::new_sparse(steps, d);
            let t0 = Instant::now();
            for (q, nk, nv) in &decodes {
                store.append(nk, nv).unwrap();
                let rows = store.len().div_ceil(quantum) * quantum;
                let (kp, vp, valid) = store.padded(rows);
                let item = AttendItem {
                    query: q,
                    keys: kp,
                    values: vp,
                    prefix_rows: valid,
                    packed: Some(store.packed_view(rows)),
                };
                sparse_outs.push(backend.attend_batch(&[item]).unwrap().remove(0));
            }
            let ns_sparse = t0.elapsed().as_nanos() as f64 / steps as f64;

            // (d) the serving default since ISSUE 7: the fused FlashCAM
            // streaming kernel + incremental bits
            let mut fused_outs: Vec<Vec<f32>> = Vec::with_capacity(steps);
            let mut fused_store = KvStore::new(steps, d, d);
            let mut fused_backend = FunctionalBackend::new(steps, d);
            let t0 = Instant::now();
            for (q, nk, nv) in &decodes {
                fused_store.append(nk, nv).unwrap();
                let rows = fused_store.len().div_ceil(quantum) * quantum;
                let (kp, vp, valid) = fused_store.padded(rows);
                let item = AttendItem {
                    query: q,
                    keys: kp,
                    values: vp,
                    prefix_rows: valid,
                    packed: Some(fused_store.packed_view(rows)),
                };
                fused_outs.push(fused_backend.attend_batch(&[item]).unwrap().remove(0));
            }
            let ns_fused = t0.elapsed().as_nanos() as f64 / steps as f64;

            assert_eq!(dense_outs, dense_inc_outs, "incremental bits diverged at n={steps}");
            assert_eq!(dense_outs, sparse_outs, "sparse pipeline diverged at n={steps}");
            assert_eq!(dense_outs, fused_outs, "fused kernel diverged at n={steps}");
            // the asymptotic contract, in exact work counters:
            let w = backend.work;
            assert_eq!(w.attends, steps as u64);
            assert!(
                w.v_rows_touched <= w.attends * 32,
                "sparse contextualization must touch ≤ final_k rows/step \
                 (touched {} over {} steps)",
                w.v_rows_touched,
                w.attends
            );
            assert_eq!(w.fallback_rows_packed, 0, "store bits must reach the backend");
            assert_eq!(
                store.packed_rows_total(),
                steps as u64,
                "each append must pack exactly one row (no full repack)"
            );
            // the fused kernel's work is analytic: at d = 64 each live
            // row costs exactly one u64 word, step i has i live rows, and
            // the stream covers ceil(i/16) tiles — pad rows and the
            // n-length score vector cost nothing
            let wf = fused_backend.work;
            assert_eq!(wf.attends, steps as u64);
            assert_eq!(
                wf.words_scored,
                (steps as u64 * (steps as u64 + 1)) / 2,
                "fused scoring must cost one word per live row at d=64"
            );
            assert_eq!(
                wf.tiles_streamed,
                (1..=steps as u64).map(|i| i.div_ceil(16)).sum::<u64>(),
                "fused streaming must cover ceil(len/16) tiles per step"
            );
            assert!(
                wf.v_rows_touched <= wf.attends * 32,
                "fused contextualization must touch ≤ final_k rows/step"
            );
            assert_eq!(wf.fallback_rows_packed, 0, "store bits must reach the fused kernel");
            assert!(
                wf.survivor_corrections > 0,
                "long streams must actually exercise online survivor eviction"
            );
            for (label, ns) in [
                ("dense_full_repack", ns_dense),
                ("dense_incremental", ns_dense_inc),
                ("sparse_incremental", ns_sparse),
                ("fused_incremental", ns_fused),
            ] {
                println!("bench long_context_{label}_n{steps:<5} {:>12.2} us/step", ns / 1e3);
                hotpath_json.push((format!("long_context_{label}_n{steps}"), ns));
            }
        }
    }

    // macro: bursty open-loop arrivals against the standing scheduler
    // (ISSUE 6) — 16 sessions submit jittered decode bursts faster than
    // a deliberately slow backend can drain them, through a queue
    // bounded at max_queue = 8 and an exactly-fitting shared KV budget.
    // Overload sheds are replayed until admission (the retryable
    // contract), and while the backend is busy the standing queue
    // backs up, so the next plan extends across many waiting sessions:
    // occupancy must exceed 1, sheds must actually fire, and the pool
    // high-water mark must never exceed the budget.
    {
        struct SlowBackend {
            inner: FunctionalBackend,
            delay: Duration,
        }
        impl AttentionBackend for SlowBackend {
            fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> anyhow::Result<Vec<f32>> {
                self.inner.attend(q, k, v)
            }
            fn attend_batch(&mut self, items: &[AttendItem<'_>]) -> anyhow::Result<Vec<Vec<f32>>> {
                // one fixed-latency accelerator round trip per dispatch:
                // batching amortises it, sequential dispatch pays it per query
                std::thread::sleep(self.delay);
                self.inner.attend_batch(items)
            }
            fn name(&self) -> &'static str {
                "slow-functional"
            }
        }

        let sessions = 16usize;
        let steps = 8usize;
        let prefill_rows = 8usize;
        let capacity = 64usize;
        // exact fit: the budget binds (hwm reaches it) without refusing
        let budget = sessions * (prefill_rows + steps);
        let mut bc = Bencher::coarse();
        let mut best_occupancy = 0.0f64;
        let mut sheds_seen = 0u64;
        let mut best_ns = f64::INFINITY;
        bc.bench("bursty_open_loop_16sess_q8", || {
            let server = CamformerServer::start(
                ServerConfig {
                    kv_capacity: capacity,
                    max_sessions: sessions,
                    batch: BatchPolicy::bounds(16, Duration::from_micros(200)),
                    worker_kv_budget: budget,
                    max_queue: 8,
                    ..Default::default()
                },
                |_| SlowBackend {
                    inner: FunctionalBackend::new(capacity, 64),
                    delay: Duration::from_micros(200),
                },
            );
            let mut rng2 = Rng::new(15);
            let handles: Vec<SessionHandle<'_>> = (0..sessions as u64)
                .map(|sid| {
                    let keys = rng2.normal_vec(prefill_rows * 64);
                    let values = rng2.normal_vec(prefill_rows * 64);
                    loop {
                        match server.open(sid, keys.clone(), values.clone()) {
                            Ok(h) => break h,
                            Err(ServeError::Overloaded { .. }) => std::thread::yield_now(),
                            Err(e) => panic!("open refused terminally: {e}"),
                        }
                    }
                })
                .collect();
            let t0 = Instant::now();
            let mut tickets = Vec::with_capacity(sessions * steps);
            for step in 0..steps {
                for (si, h) in handles.iter().enumerate() {
                    // open-loop jitter: a short stall every few arrivals
                    if (si + step) % 5 == 0 {
                        std::thread::sleep(Duration::from_micros(20));
                    }
                    let q = rng2.normal_vec(64);
                    let nk = rng2.normal_vec(64);
                    let nv = rng2.normal_vec(64);
                    let t = loop {
                        match h.decode(q.clone(), nk.clone(), nv.clone()) {
                            Ok(t) => break t,
                            Err(ServeError::Overloaded { .. }) => {
                                sheds_seen += 1;
                                std::thread::yield_now();
                            }
                            Err(e) => panic!("burst decode refused terminally: {e}"),
                        }
                    };
                    tickets.push(t);
                }
            }
            let total = tickets.len();
            for t in tickets {
                assert!(t.wait().is_ok(), "bursty decode failed");
            }
            best_ns = best_ns.min(t0.elapsed().as_nanos() as f64 / total as f64);
            drop(handles);
            let (m, w) = server.shutdown();
            assert!(m.kv_rows_hwm <= budget as u64, "pool residency broke the budget");
            best_occupancy = best_occupancy.max(m.mean_occupancy());
            (m.decodes, w)
        });
        println!(
            "      bursty_open_loop: occupancy {best_occupancy:.2}x, {sheds_seen} sheds \
             replayed to admission (queue bounded at 8)"
        );
        assert!(
            best_occupancy > 1.0,
            "a backlogged standing queue must extend plans past one query/dispatch \
             (occupancy {best_occupancy:.2}x)"
        );
        assert!(sheds_seen > 0, "the open-loop burst must overrun max_queue = 8 and shed");
        hotpath_json.push(("bursty_open_loop_16sess_q8".to_string(), best_ns));
    }

    // macro: spill-tier churn (ISSUE 8) — 8 sessions against a shared KV
    // budget that holds only 4, under LruSpillToDram: every over-budget
    // open demotes the shard-LRU victim's KV into the simulated host
    // DRAM tier, and each round-robin attend of a demoted session
    // promotes it back (demoting another) — steady-state thrash where
    // EVERY attend pays a promotion, pricing the spill tier's hot path.
    // The demote/promote decision counts and the modeled DRAM traffic
    // are emitted alongside ns/op so tools/check_bench.py can watch the
    // spill tier stay live across PRs.
    {
        let sessions = 8usize;
        let prefill_rows = 16usize;
        let rounds = 4usize;
        let capacity = 32usize;
        // the resident tier holds exactly half the population
        let budget = 4 * prefill_rows;
        let mut bc = Bencher::coarse();
        let mut best_ns = f64::INFINITY;
        let mut last = (0u64, 0u64, 0u64);
        bc.bench("spill_churn_8sess_budget64", || {
            let server = CamformerServer::start(
                ServerConfig {
                    kv_capacity: capacity,
                    max_sessions: sessions,
                    reclaim: ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
                    batch: BatchPolicy::bounds(16, Duration::from_micros(200)),
                    worker_kv_budget: budget,
                    ..Default::default()
                },
                |_| FunctionalBackend::new(capacity, 64),
            );
            let mut rng2 = Rng::new(16);
            let handles: Vec<SessionHandle<'_>> = (0..sessions as u64)
                .map(|sid| {
                    let keys = rng2.normal_vec(prefill_rows * 64);
                    let values = rng2.normal_vec(prefill_rows * 64);
                    server
                        .open(sid, keys, values)
                        .expect("spill admission must demote, never refuse")
                })
                .collect();
            let t0 = Instant::now();
            let mut served = 0u64;
            for _round in 0..rounds {
                for h in &handles {
                    let r = h.attend(rng2.normal_vec(64)).unwrap().wait();
                    assert!(r.is_ok(), "spill-tier attend failed");
                    assert_eq!(r.seq_len(), prefill_rows, "promotion must restore every row");
                    served += 1;
                }
            }
            best_ns = best_ns.min(t0.elapsed().as_nanos() as f64 / served as f64);
            for h in handles {
                h.close().unwrap();
            }
            let (m, w) = server.shutdown();
            assert_eq!(m.evictions, 0, "the spill tier must never drop a session");
            assert_eq!(m.errors, 0, "the spill tier must never refuse a request");
            assert!(m.demotions > 0 && m.promotions > 0, "churn must spill AND promote");
            assert!(m.dram_bytes_written > 0 && m.dram_bytes_read > 0, "no DRAM traffic modeled");
            last = (m.demotions, m.promotions, m.dram_bytes_written + m.dram_bytes_read);
            (served, w)
        });
        println!(
            "      spill_churn: demotions={} promotions={} dram_bytes={} \
             (8 sessions through a 4-session resident tier)",
            last.0, last.1, last.2
        );
        hotpath_json.push(("spill_churn_8sess_budget64".to_string(), best_ns));
        hotpath_json.push(("spill_churn_demotions".to_string(), last.0 as f64));
        hotpath_json.push(("spill_churn_promotions".to_string(), last.1 as f64));
        hotpath_json.push(("spill_churn_dram_bytes".to_string(), last.2 as f64));
    }

    // macro: chaos restart (ISSUE 9) — the spill-churn population served
    // through a ChaosBackend that crashes the worker on the 16th dispatch
    // of every incarnation. Each crash exercises the whole recovery path:
    // the supervisor respawns the backend onto the same queue, in-flight
    // tickets resolve WorkerGone, resident sessions come back SessionLost
    // (the bench re-opens them, as a client would), and DRAM-spilled
    // sessions recover byte-identically from the shard directory's pool.
    // ns/op prices serving THROUGH the crash/restart cycles, and the
    // restart/lost/recovered counters are emitted so tools/check_bench.py
    // can watch the recovery path stay live across PRs.
    {
        let sessions = 8usize;
        let prefill_rows = 16usize;
        let rounds = 8usize;
        let capacity = 32usize;
        // the resident tier holds half the population, so every crash
        // loses ~4 resident sessions while ~4 spilled ones survive
        let budget = 4 * prefill_rows;
        let mut bc = Bencher::coarse();
        let mut best_ns = f64::INFINITY;
        let mut last = (0u64, 0u64, 0u64);
        bc.bench("chaos_restart_8sess_crash_every_16", || {
            let server = CamformerServer::start(
                ServerConfig {
                    kv_capacity: capacity,
                    max_sessions: sessions,
                    reclaim: ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
                    batch: BatchPolicy::bounds(16, Duration::from_micros(200)),
                    worker_kv_budget: budget,
                    ..Default::default()
                },
                |_| {
                    ChaosBackend::new(
                        FunctionalBackend::new(capacity, 64),
                        FaultPlan::at(vec![(16, Fault::Crash)]),
                    )
                },
            );
            let mut rng2 = Rng::new(17);
            let kv: Vec<(Vec<f32>, Vec<f32>)> = (0..sessions)
                .map(|_| {
                    (rng2.normal_vec(prefill_rows * 64), rng2.normal_vec(prefill_rows * 64))
                })
                .collect();
            let mut id = 0u64;
            for (sid, (keys, values)) in kv.iter().enumerate() {
                let t = server
                    .submit_ticket(Request::Prefill {
                        id: 100_000 + sid as u64,
                        session: sid as u64,
                        head: 0,
                        keys: keys.clone(),
                        values: values.clone(),
                    })
                    .unwrap();
                assert!(t.wait().is_ok(), "chaos prefill refused");
            }
            let t0 = Instant::now();
            let mut served = 0u64;
            for _round in 0..rounds {
                for sid in 0..sessions as u64 {
                    // serve one attend, riding out crashes: a SessionLost
                    // session is re-opened (the client-side recovery the
                    // error contract prescribes), WorkerGone / injected
                    // faults simply retry against the respawned worker
                    loop {
                        let q = rng2.normal_vec(64);
                        let t = server
                            .submit_ticket(Request::Attend { id, session: sid, head: 0, query: q })
                            .unwrap();
                        id += 1;
                        let r = t.wait();
                        match &r.result {
                            Ok(out) => {
                                assert_eq!(
                                    out.seq_len, prefill_rows,
                                    "recovery must restore every row"
                                );
                                served += 1;
                                break;
                            }
                            Err(ServeError::SessionLost { .. }) => {
                                let (keys, values) = &kv[sid as usize];
                                let p = server
                                    .submit_ticket(Request::Prefill {
                                        id: 200_000 + id,
                                        session: sid,
                                        head: 0,
                                        keys: keys.clone(),
                                        values: values.clone(),
                                    })
                                    .unwrap();
                                assert!(p.wait().is_ok(), "chaos re-open refused");
                            }
                            Err(ServeError::WorkerGone { .. } | ServeError::Backend(_)) => {}
                            Err(e) => panic!("chaos attend failed terminally: {e}"),
                        }
                    }
                }
            }
            best_ns = best_ns.min(t0.elapsed().as_nanos() as f64 / served as f64);
            let (m, w) = server.shutdown();
            assert!(m.worker_restarts > 0, "the crash plan must force at least one restart");
            assert!(m.sessions_lost > 0, "a crash must lose its resident sessions");
            assert!(m.sessions_recovered > 0, "spilled sessions must survive the crash");
            last = (m.worker_restarts, m.sessions_lost, m.sessions_recovered);
            (served, w)
        });
        println!(
            "      chaos_restart: restarts={} lost={} recovered={} \
             (8 sessions, crash every 16th dispatch, spill tier live)",
            last.0, last.1, last.2
        );
        hotpath_json.push(("chaos_restart_8sess_crash_every_16".to_string(), best_ns));
        hotpath_json.push(("chaos_restart_worker_restarts".to_string(), last.0 as f64));
        hotpath_json.push(("chaos_restart_sessions_lost".to_string(), last.1 as f64));
        hotpath_json.push(("chaos_restart_sessions_recovered".to_string(), last.2 as f64));
    }

    // machine-readable perf trajectory (scenario -> ns/step), tracked
    // across PRs
    let mut json = String::from("{\n");
    for (i, (name, ns)) in hotpath_json.iter().enumerate() {
        let sep = if i + 1 < hotpath_json.len() { "," } else { "" };
        json.push_str(&format!("  \"{name}\": {ns:.1}{sep}\n"));
    }
    json.push_str("}\n");
    std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    println!("      wrote BENCH_hotpath.json ({} scenarios)", hotpath_json.len());

    print!("{}", b.summary());
}
