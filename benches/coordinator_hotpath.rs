//! Bench: the Layer-3 serving hot path — request->batch->execute->respond
//! round trips through the coordinator, plus the micro-costs (bf16 dot,
//! softmax engine, batcher overhead) that dominate it.

use std::time::Duration;

use camformer::arch::softmax::SoftmaxEngine;
use camformer::coordinator::backend::FunctionalBackend;
use camformer::coordinator::batcher::BatchPolicy;
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::util::bench::Bencher;
use camformer::util::{bf16, rng::Rng};

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(8);

    // micro: bf16 weighted-sum inner loop (the contextualization kernel)
    let a = rng.normal_vec(64);
    let v = rng.normal_vec(64);
    b.bench("bf16_dot_64", || bf16::dot(&a, &v));

    // micro: softmax engine
    let eng = SoftmaxEngine::new(64);
    let scores: Vec<f64> = (0..32).map(|_| rng.range(0, 129) as f64 - 64.0).collect();
    b.bench("softmax_engine_32", || eng.normalize(&scores));

    // macro: full serving round trips through the functional backend
    for (label, heads, requests) in [("1head", 1usize, 64usize), ("4heads", 4, 256)] {
        let n = 1024;
        let mut kv_rng = Rng::new(9);
        let kv: Vec<(Vec<f32>, Vec<f32>)> = (0..heads)
            .map(|_| (kv_rng.normal_vec(n * 64), kv_rng.normal_vec(n * 64)))
            .collect();
        let mut bc = Bencher::coarse();
        bc.bench(&format!("serve_roundtrip_{label}_{requests}req"), || {
            let kvc = kv.clone();
            let server = CamformerServer::start(
                ServerConfig {
                    heads,
                    batch: BatchPolicy {
                        max_batch: 16,
                        max_wait: Duration::from_micros(200),
                    },
                },
                |_| FunctionalBackend::new(n, 64),
                move |h| kvc[h].clone(),
            );
            let mut qrng = Rng::new(10);
            for i in 0..requests {
                server
                    .submit(Request {
                        id: i as u64,
                        head: i % heads,
                        query: qrng.normal_vec(64),
                    })
                    .unwrap();
            }
            let resps = server.collect(requests);
            assert_eq!(resps.len(), requests);
            let (m, w) = server.shutdown();
            (m.completed, w)
        });
    }

    print!("{}", b.summary());
}
