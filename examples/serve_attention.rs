//! Serving scenario: session-oriented decode serving through the Layer-3
//! coordinator's session-handle API — `open` a handle per session (one
//! shard-wide prefill fan-out), stream live decode steps whose (k, v)
//! pairs append to each session's KV cache ("CAM search over a growing
//! KV cache each step", Sec. IV-C) with each step's result arriving on
//! its own typed `Ticket`, then `close` every session. A lifecycle
//! epilogue over-subscribes a small worker under
//! `ReclaimPolicy::LruEvictIdle` to show admission evicting idle
//! sessions instead of failing, and a budget epilogue squeezes several
//! sessions into a shared per-worker KV row pool
//! (`ServerConfig::worker_kv_budget`) to show the standing scheduler's
//! pool admission reclaiming idle rows the same way. A chaos epilogue
//! crashes a worker mid-serving through a `ChaosBackend` fault plan and
//! shows the supervised recovery contract end to end: the in-flight
//! ticket resolves typed, the respawned worker recovers a DRAM-spilled
//! session byte-for-byte, and a crash-lost session answers
//! `SessionLost` until a re-`open` revives it.
//!
//! ```bash
//! cargo run --release --example serve_attention \
//!     [-- --sessions 8 --steps 64 --heads 4 --backend functional|arch|pjrt]
//! ```
//!
//! Reports serving latency percentiles (p50/p99), throughput and the
//! session lifecycle counters, and golden-checks a final query per
//! session against the pure-Rust functional model applied to the
//! accumulated K/V. The `pjrt` backend needs `make artifacts` and a
//! build with `--features pjrt`.

use std::time::Duration;

use anyhow::Result;
use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::{
    ArchSimBackend, ChaosBackend, Fault, FaultPlan, FunctionalBackend, PjrtBackend,
};
use camformer::coordinator::error::ServeError;
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, ReclaimPolicy, ServerConfig};
use camformer::coordinator::{SessionHandle, Ticket};
use camformer::runtime::executable::default_artifacts_dir;
use camformer::util::cli::Args;
use camformer::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let heads = args.get_usize("heads", 4);
    let sessions = args.get_usize("sessions", 8);
    let steps = args.get_usize("steps", 64);
    let backend_kind = args.get_or("backend", "functional");
    let d = 64usize;
    let capacity = 1024usize;
    let prefill_rows = 128usize;

    println!(
        "serve_attention: {sessions} sessions x {steps} decode steps over {heads} heads, \
         {backend_kind} backend"
    );

    let cfg = ServerConfig {
        heads,
        kv_capacity: capacity,
        max_sessions: sessions.max(1),
        ..Default::default()
    };
    let quantum = cfg.pad_quantum;
    let server = match backend_kind {
        "functional" => {
            CamformerServer::start(cfg, |_| FunctionalBackend::new(capacity, d))
        }
        "arch" => CamformerServer::start(cfg, |_| ArchSimBackend::new(capacity)),
        "pjrt" => {
            let dir = default_artifacts_dir();
            CamformerServer::start(cfg, move |w| {
                PjrtBackend::new(&dir).unwrap_or_else(|e| panic!("worker {w}: {e:#}"))
            })
        }
        other => anyhow::bail!("unknown backend {other:?} (functional|arch|pjrt)"),
    };

    // one `open` per session broadcasts the prompt K/V to every head of
    // the shard (all-or-nothing), so a single head-0 mirror per session
    // is enough for the golden replay (in a real deployment the XPU
    // owns these tensors)
    let mut rng = Rng::new(7);
    let mut mirrors: Vec<KvStore> =
        (0..sessions).map(|_| KvStore::new(capacity, d, d)).collect();
    let mut handles: Vec<SessionHandle<'_>> = Vec::with_capacity(sessions);
    for sid in 0..sessions as u64 {
        let keys = rng.normal_vec(prefill_rows * d);
        let values = rng.normal_vec(prefill_rows * d);
        mirrors[sid as usize].load(&keys, &values)?;
        handles.push(server.open(sid, keys, values)?);
    }

    // interleaved decode streams: every step appends one (k, v) per
    // head; the whole workload is submitted before any wait so the
    // workers' wire batches stay full, and every step's response comes
    // back on its own ticket (no id correlation)
    let mut tickets: Vec<Ticket> = Vec::with_capacity(sessions * heads * steps);
    for _step in 0..steps {
        for (sid, handle) in handles.iter().enumerate() {
            for h in 0..heads {
                let q = rng.normal_vec(d);
                let nk = rng.normal_vec(d);
                let nv = rng.normal_vec(d);
                if h == 0 {
                    mirrors[sid].append(&nk, &nv)?;
                }
                tickets.push(handle.decode_on(h, q, nk, nv)?);
            }
        }
    }
    let total = tickets.len();
    let mut failed = 0usize;
    for t in tickets {
        if t.wait().result.is_err() {
            failed += 1;
        }
    }
    anyhow::ensure!(failed == 0, "{failed} of {total} decode steps failed");

    // golden check: one final Attend per session against the functional
    // model over the accumulated cache
    for (sid, handle) in handles.iter().enumerate() {
        let q = rng.normal_vec(d);
        let r = handle.attend(q.clone())?.wait();
        anyhow::ensure!(r.is_ok(), "golden attend failed: {:?}", r.result);
        let store = &mirrors[sid];
        // the reference must replay the backend's execution geometry: the
        // PJRT artifacts are compiled for a fixed 1024-row context, the
        // flexible backends pad to the stage-1 group quantum
        let rows = match backend_kind {
            "pjrt" => capacity,
            _ => store.len().div_ceil(quantum) * quantum,
        };
        let (kp, vp, _) = store.padded(rows);
        let want = functional::camformer_attention(&q, kp, vp, &AttnConfig::paper(rows, d));
        for (a, b) in r.output().iter().zip(&want) {
            anyhow::ensure!((a - b).abs() < 5e-2, "golden mismatch: {a} vs {b}");
        }
    }
    println!(
        "golden checks passed ({} sessions, live cache length {})",
        sessions,
        prefill_rows + steps
    );

    // explicit lifecycle teardown: every close frees the session's
    // provisioned KV capacity on all heads
    for handle in handles {
        handle.close()?;
    }
    let (metrics, window) = server.shutdown();
    println!("{}", metrics.summary(window));

    // lifecycle epilogue: a worker capped at 2 sessions keeps admitting
    // an 8-session population because LruEvictIdle reclaims the
    // least-recently-used idle session per over-limit open — previously
    // these opens were terminal SessionLimit errors
    let churn_cfg = ServerConfig {
        kv_capacity: 64,
        max_sessions: 2,
        reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
        ..Default::default()
    };
    let churn = CamformerServer::start(churn_cfg, |_| FunctionalBackend::new(64, d));
    let mut resident: Vec<SessionHandle<'_>> = Vec::new();
    for sid in 0..8u64 {
        let h = churn.open(sid, rng.normal_vec(16 * d), rng.normal_vec(16 * d))?;
        let r = h
            .decode(rng.normal_vec(d), rng.normal_vec(d), rng.normal_vec(d))?
            .wait();
        anyhow::ensure!(r.is_ok(), "churn decode failed: {:?}", r.result);
        // keep every handle alive: capacity pressure must be resolved by
        // the reclaim policy, not by our closes
        resident.push(h);
    }
    drop(resident);
    let (m, w) = churn.shutdown();
    anyhow::ensure!(m.evictions > 0, "over-subscribed opens must have evicted");
    println!(
        "lifecycle: 8 opens on a 2-session worker -> {} evictions, {} closes, \
         {} KV rows released ({})",
        m.evictions,
        m.closes,
        m.kv_rows_released,
        m.summary(w)
    );

    // budget epilogue: the standing scheduler also admits against a
    // SHARED per-worker KV row pool. Four sessions of 49 rows each can
    // never be resident together in a 96-row pool, so every over-pool
    // prefill evicts the LRU idle session's rows instead of failing —
    // and the pool high-water mark proves admission never overshot
    let pool_cfg = ServerConfig {
        kv_capacity: 64,
        max_sessions: 8,
        worker_kv_budget: 96,
        reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
        ..Default::default()
    };
    let pool = CamformerServer::start(pool_cfg, |_| FunctionalBackend::new(64, d));
    let mut pooled: Vec<SessionHandle<'_>> = Vec::new();
    for sid in 0..4u64 {
        let h = pool.open(sid, rng.normal_vec(48 * d), rng.normal_vec(48 * d))?;
        let r = h
            .decode(rng.normal_vec(d), rng.normal_vec(d), rng.normal_vec(d))?
            .wait();
        anyhow::ensure!(r.is_ok(), "pooled decode failed: {:?}", r.result);
        pooled.push(h);
    }
    drop(pooled);
    let (m, w) = pool.shutdown();
    anyhow::ensure!(m.evictions > 0, "over-pool prefills must have evicted");
    anyhow::ensure!(m.kv_rows_hwm <= 96, "pool residency broke the budget");
    println!(
        "kv budget: 4 x 49-row sessions against a 96-row pool -> residency hwm {} <= 96, \
         {} evictions ({})",
        m.kv_rows_hwm,
        m.evictions,
        m.summary(w)
    );

    // chaos epilogue (ISSUE 9): a fault plan crashes the worker on its
    // 2nd dispatch. Four 16-row sessions over a 32-row budget leave two
    // resident and two spilled to DRAM when the crash lands, so one run
    // shows the whole recovery contract: the in-flight ticket resolves
    // typed instead of hanging, the supervisor respawns the worker, a
    // spilled session promotes back with every row intact, the lost
    // resident answers `SessionLost` until re-opened
    let chaos_cfg = ServerConfig {
        kv_capacity: 64,
        max_sessions: 4,
        worker_kv_budget: 32,
        reclaim: ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
        ..Default::default()
    };
    let chaos = CamformerServer::start(chaos_cfg, |_| {
        ChaosBackend::new(
            FunctionalBackend::new(64, d),
            FaultPlan::at(vec![(2, Fault::Crash)]),
        )
    });
    let mut chaos_handles: Vec<SessionHandle<'_>> = Vec::new();
    for sid in 0..4u64 {
        chaos_handles.push(chaos.open(sid, rng.normal_vec(16 * d), rng.normal_vec(16 * d))?);
    }
    // sessions 0 and 1 were demoted by the over-budget opens; 2 and 3 are
    // resident. Waiting each attend before the next keeps one dispatch
    // per request, so the crash lands exactly on session 2's attend.
    let r = chaos_handles[3].attend(rng.normal_vec(d))?.wait();
    anyhow::ensure!(r.is_ok(), "pre-crash attend failed: {:?}", r.result);
    let r = chaos_handles[2].attend(rng.normal_vec(d))?.wait();
    anyhow::ensure!(
        matches!(
            r.result,
            Err(ServeError::WorkerGone { .. }) | Err(ServeError::SessionLost { .. })
        ),
        "the crashed dispatch must resolve typed, got {:?}",
        r.result
    );
    // the respawned worker promotes the spilled session out of the shard
    // directory's DRAM pool — the crash never touched those bytes
    let r = chaos_handles[0].attend(rng.normal_vec(d))?.wait();
    anyhow::ensure!(r.is_ok(), "post-crash recovery attend failed: {:?}", r.result);
    anyhow::ensure!(r.seq_len() == 16, "recovered session lost rows: {}", r.seq_len());
    // the crash-lost resident stays typed until a re-open revives it
    let r = chaos_handles[2].attend(rng.normal_vec(d))?.wait();
    anyhow::ensure!(
        matches!(r.result, Err(ServeError::SessionLost { session: 2 })),
        "a lost session must answer SessionLost, got {:?}",
        r.result
    );
    let reopened = chaos.open(2, rng.normal_vec(16 * d), rng.normal_vec(16 * d))?;
    drop(reopened);
    drop(chaos_handles);
    let (m, w) = chaos.shutdown();
    anyhow::ensure!(m.worker_restarts >= 1, "the crash must have forced a restart");
    anyhow::ensure!(m.sessions_lost >= 1, "the crash must have lost its residents");
    anyhow::ensure!(m.sessions_recovered >= 1, "a spilled session must have recovered");
    println!(
        "chaos: injected worker crash -> {} restart(s), {} session(s) lost typed, \
         {} recovered from the spill tier ({})",
        m.worker_restarts,
        m.sessions_lost,
        m.sessions_recovered,
        m.summary(w)
    );

    println!(
        "\n(simulated CAMformer silicon would serve this at {:.0} qry/ms/head — `camformer table2`)",
        camformer::arch::pipeline::PipelineModel::paper().throughput_qry_per_ms()
    );
    Ok(())
}
