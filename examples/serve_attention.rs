//! Serving scenario: the Layer-3 coordinator batches a stream of attention
//! queries over multiple heads and executes them on the PJRT artifacts —
//! CAMformer as deployed next to an XPU (Sec. III-A).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_attention [-- --requests 512 --heads 4]
//! ```
//!
//! Reports serving latency percentiles and throughput, and golden-checks a
//! sample of responses against the pure-Rust functional model.

use anyhow::Result;
use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::PjrtBackend;
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::runtime::executable::default_artifacts_dir;
use camformer::util::cli::Args;
use camformer::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let heads = args.get_usize("heads", 4);
    let requests = args.get_usize("requests", 256);
    let n = 1024usize;
    let d = 64usize;

    println!("serve_attention: {requests} requests, {heads} heads, PJRT backend");
    let dir = default_artifacts_dir();

    // per-head KV memories (in a real deployment the XPU writes these into
    // shared memory; here a seeded generator stands in)
    let mut kv_rng = Rng::new(7);
    let kv: Vec<(Vec<f32>, Vec<f32>)> = (0..heads)
        .map(|_| (kv_rng.normal_vec(n * d), kv_rng.normal_vec(n * d)))
        .collect();

    let kv_clone = kv.clone();
    let server = CamformerServer::start(
        ServerConfig { heads, ..Default::default() },
        |h| PjrtBackend::new(&dir).unwrap_or_else(|e| panic!("head {h}: {e:#}")),
        move |h| kv_clone[h].clone(),
    );

    // deterministic query stream
    let mut rng = Rng::new(8);
    let queries: Vec<Vec<f32>> = (0..requests).map(|_| rng.normal_vec(d)).collect();
    for (i, q) in queries.iter().enumerate() {
        server
            .submit(Request { id: i as u64, head: i % heads, query: q.clone() })
            .map_err(anyhow::Error::msg)?;
    }
    let resps = server.collect(requests);

    // golden check a sample
    let cfg = AttnConfig::paper(n, d);
    for r in resps.iter().step_by(requests / 8).take(8) {
        let (k, v) = &kv[r.head];
        let want = functional::camformer_attention(&queries[r.id as usize], k, v, &cfg);
        for (a, b) in r.output.iter().zip(&want) {
            assert!((a - b).abs() < 5e-2, "golden mismatch: {a} vs {b}");
        }
    }
    println!("golden checks passed");

    let (metrics, window) = server.shutdown();
    println!("{}", metrics.summary(window));
    println!(
        "\n(simulated CAMformer silicon would serve this at {:.0} qry/ms/head — `camformer table2`)",
        camformer::arch::pipeline::PipelineModel::paper().throughput_qry_per_ms()
    );
    Ok(())
}
