//! Serving scenario: session-oriented decode serving through the Layer-3
//! coordinator — prefill a prompt per session, then stream live decode
//! steps whose (k, v) pairs append to each session's KV cache ("CAM
//! search over a growing KV cache each step", Sec. IV-C).
//!
//! ```bash
//! cargo run --release --example serve_attention \
//!     [-- --sessions 8 --steps 64 --heads 4 --backend functional|arch|pjrt]
//! ```
//!
//! Reports serving latency percentiles (p50/p99) and throughput, and
//! golden-checks a final query per session against the pure-Rust
//! functional model applied to the accumulated K/V. The `pjrt` backend
//! needs `make artifacts` and a build with `--features pjrt`.

use anyhow::Result;
use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::{ArchSimBackend, FunctionalBackend, PjrtBackend};
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::runtime::executable::default_artifacts_dir;
use camformer::util::cli::Args;
use camformer::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let heads = args.get_usize("heads", 4);
    let sessions = args.get_usize("sessions", 8);
    let steps = args.get_usize("steps", 64);
    let backend_kind = args.get_or("backend", "functional");
    let d = 64usize;
    let capacity = 1024usize;
    let prefill_rows = 128usize;

    println!(
        "serve_attention: {sessions} sessions x {steps} decode steps over {heads} heads, \
         {backend_kind} backend"
    );

    let cfg = ServerConfig {
        heads,
        kv_capacity: capacity,
        max_sessions: sessions.max(1),
        ..Default::default()
    };
    let quantum = cfg.pad_quantum;
    let server = match backend_kind {
        "functional" => {
            CamformerServer::start(cfg, |_| FunctionalBackend::new(capacity, d))
        }
        "arch" => CamformerServer::start(cfg, |_| ArchSimBackend::new(capacity)),
        "pjrt" => {
            let dir = default_artifacts_dir();
            CamformerServer::start(cfg, move |w| {
                PjrtBackend::new(&dir).unwrap_or_else(|e| panic!("worker {w}: {e:#}"))
            })
        }
        other => anyhow::bail!("unknown backend {other:?} (functional|arch|pjrt)"),
    };

    // per-(session, head) mirrors so the golden check can replay the
    // accumulated K/V (in a real deployment the XPU owns these tensors)
    let mut rng = Rng::new(7);
    let mut mirrors: Vec<Vec<KvStore>> = (0..sessions)
        .map(|_| (0..heads).map(|_| KvStore::new(capacity, d, d)).collect())
        .collect();

    let mut next_id = 0u64;
    for sid in 0..sessions as u64 {
        for h in 0..heads {
            let keys = rng.normal_vec(prefill_rows * d);
            let values = rng.normal_vec(prefill_rows * d);
            mirrors[sid as usize][h].load(&keys, &values).map_err(anyhow::Error::msg)?;
            server
                .submit(Request::Prefill { id: next_id, session: sid, head: h, keys, values })
                .map_err(anyhow::Error::msg)?;
            next_id += 1;
        }
    }
    let acks = server.collect(sessions * heads);
    anyhow::ensure!(acks.iter().all(|a| a.is_ok()), "prefill failed");

    // interleaved decode streams: every step appends one (k, v) per head
    for _step in 0..steps {
        for sid in 0..sessions as u64 {
            for h in 0..heads {
                let q = rng.normal_vec(d);
                let nk = rng.normal_vec(d);
                let nv = rng.normal_vec(d);
                mirrors[sid as usize][h].append(&nk, &nv).map_err(anyhow::Error::msg)?;
                server
                    .submit(Request::Decode {
                        id: next_id,
                        session: sid,
                        head: h,
                        query: q,
                        new_key: nk,
                        new_value: nv,
                    })
                    .map_err(anyhow::Error::msg)?;
                next_id += 1;
            }
        }
    }
    let total = sessions * heads * steps;
    let resps = server.collect(total);
    let failed = resps.iter().filter(|r| !r.is_ok()).count();
    anyhow::ensure!(failed == 0, "{failed} decode steps failed");

    // golden check: one final Attend per session against the functional
    // model over the accumulated cache
    let mut golden_q = Vec::new();
    for sid in 0..sessions as u64 {
        let q = rng.normal_vec(d);
        server
            .submit(Request::Attend { id: next_id, session: sid, head: 0, query: q.clone() })
            .map_err(anyhow::Error::msg)?;
        golden_q.push((next_id, sid, q));
        next_id += 1;
    }
    let finals = server.collect(sessions);
    for r in &finals {
        let (_, sid, q) = golden_q.iter().find(|(id, _, _)| *id == r.id).unwrap();
        let store = &mirrors[*sid as usize][0];
        // the reference must replay the backend's execution geometry: the
        // PJRT artifacts are compiled for a fixed 1024-row context, the
        // flexible backends pad to the stage-1 group quantum
        let rows = match backend_kind {
            "pjrt" => capacity,
            _ => store.len().div_ceil(quantum) * quantum,
        };
        let (kp, vp, _) = store.padded(rows);
        let want = functional::camformer_attention(q, kp, vp, &AttnConfig::paper(rows, d));
        for (a, b) in r.output().iter().zip(&want) {
            anyhow::ensure!((a - b).abs() < 5e-2, "golden mismatch: {a} vs {b}");
        }
    }
    println!("golden checks passed ({} sessions, live cache length {})", sessions,
             prefill_rows + steps);

    let (metrics, window) = server.shutdown();
    println!("{}", metrics.summary(window));
    println!(
        "\n(simulated CAMformer silicon would serve this at {:.0} qry/ms/head — `camformer table2`)",
        camformer::arch::pipeline::PipelineModel::paper().throughput_qry_per_ms()
    );
    Ok(())
}
