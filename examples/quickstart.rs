//! Quickstart: one attention query through every layer of the stack,
//! ending at the serving API (`open` -> ticket `decode` -> `close`).
//!
//! ```bash
//! cargo run --release --example quickstart          # offline functional path
//! make artifacts && cargo run --release --example quickstart  # + PJRT replay
//! ```
//!
//! Flow: the pure-Rust functional model computes Eq. 1 (always
//! available); when this build has the `pjrt` feature and AOT'd Pallas
//! artifacts, PJRT replays the BA-CAM kernel (L1) and the JAX attention
//! graph (L2) and is cross-checked against it; the cycle-annotated
//! architecture simulator annotates latency; and the Layer-3 coordinator
//! serves a live decode step through a `SessionHandle`. Offline (no
//! artifacts, CI) every step except the PJRT replay still runs.

use std::time::Duration;

use anyhow::Result;
use camformer::accuracy::functional::{self, AttnConfig};
use camformer::arch::{config::ArchConfig, pipeline};
use camformer::coordinator::{CamformerServer, FunctionalBackend, ReclaimPolicy, ServerConfig};
use camformer::runtime::executable::{default_artifacts_dir, Engine};
use camformer::util::rng::Rng;

fn main() -> Result<()> {
    // synthesize a query against a 1024-entry key/value memory
    let mut rng = Rng::new(1);
    let q = rng.normal_vec(64);
    let k = rng.normal_vec(1024 * 64);
    let v = rng.normal_vec(1024 * 64);

    // L3 functional model: the golden Eq. 1 reference, always available
    let want = functional::camformer_attention(&q, &k, &v, &AttnConfig::paper(1024, 64));
    println!("functional model output (first 4 dims): {:?}", &want[..4]);

    // L1/L2: the AOT Pallas BA-CAM kernel + attention graph through
    // PJRT, when artifacts and the `pjrt` feature are present; the
    // quickstart stays fully functional offline
    let dir = default_artifacts_dir();
    match Engine::new(&dir) {
        Ok(mut engine) => {
            let scores = engine.load("bacam_scores")?.run_f32(&[&q, &k])?;
            let best = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            println!("BA-CAM: best-matching key = #{} (score {})", best.0, best.1);
            let out = engine.load("attn_single_query")?.run_f32(&[&q, &k, &v])?;
            let diff =
                out.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            println!("PJRT vs functional model: max |diff| = {diff:.6}");
            assert!(diff < 1e-2);
        }
        Err(e) => println!("PJRT replay skipped ({e:#})"),
    }

    // L3 architecture simulation: cycle-accurate latency annotation
    let (_, lat) = pipeline::simulate_query(ArchConfig::default(), &q, &k, &v);
    println!(
        "simulated hardware: {} cycles/query ({:.1} us at 1 GHz), throughput {:.0} qry/ms",
        lat.total(),
        lat.total() as f64 / 1000.0,
        pipeline::PipelineModel::paper().throughput_qry_per_ms(),
    );

    // L3 serving: the session-handle API — open admits the session
    // shard-wide, each decode returns a typed per-request ticket, close
    // releases the provisioned KV capacity
    let cfg = ServerConfig {
        kv_capacity: 1024,
        reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
        ..Default::default()
    };
    let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(1024, 64));
    let session = server.open(1, k[..512 * 64].to_vec(), v[..512 * 64].to_vec())?;
    let ticket = session.decode(q.clone(), rng.normal_vec(64), rng.normal_vec(64))?;
    let resp = ticket.wait();
    println!(
        "serving: decode step grew session {} to {} rows and returned {} dims",
        session.id(),
        resp.seq_len(),
        resp.output().len()
    );
    session.close()?;
    let (metrics, window) = server.shutdown();
    println!("serving metrics: {}", metrics.summary(window));

    println!("quickstart OK");
    Ok(())
}
