//! Quickstart: one attention query through every layer of the stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Flow: PJRT loads the AOT'd Pallas BA-CAM kernel (L1) inside the JAX
//! attention graph (L2); the pure-Rust functional model and the cycle-
//! annotated architecture simulator (L3) cross-check the numbers.

use anyhow::Result;
use camformer::accuracy::functional::{self, AttnConfig};
use camformer::arch::{config::ArchConfig, pipeline};
use camformer::runtime::executable::{default_artifacts_dir, Engine};
use camformer::util::rng::Rng;

fn main() -> Result<()> {
    let dir = default_artifacts_dir();
    println!("loading artifacts from {dir:?}");
    let mut engine = Engine::new(&dir)?;

    // synthesize a query against a 1024-entry key/value memory
    let mut rng = Rng::new(1);
    let q = rng.normal_vec(64);
    let k = rng.normal_vec(1024 * 64);
    let v = rng.normal_vec(1024 * 64);

    // L1: the BA-CAM association kernel alone
    let scores = engine.load("bacam_scores")?.run_f32(&[&q, &k])?;
    let best = scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!("BA-CAM: best-matching key = #{} (score {})", best.0, best.1);

    // L1+L2: full Eq. 1 through PJRT
    let out = engine.load("attn_single_query")?.run_f32(&[&q, &k, &v])?;
    println!("attention output (first 4 dims): {:?}", &out[..4]);

    // L3 cross-checks
    let want = functional::camformer_attention(&q, &k, &v, &AttnConfig::paper(1024, 64));
    let diff = out.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    println!("PJRT vs functional model: max |diff| = {diff:.6}");
    assert!(diff < 1e-2);

    let (_, lat) = pipeline::simulate_query(ArchConfig::default(), &q, &k, &v);
    println!(
        "simulated hardware: {} cycles/query ({:.1} us at 1 GHz), throughput {:.0} qry/ms",
        lat.total(),
        lat.total() as f64 / 1000.0,
        pipeline::PipelineModel::paper().throughput_qry_per_ms(),
    );
    println!("quickstart OK");
    Ok(())
}
