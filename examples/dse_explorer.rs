//! Design-space explorer: sweeps the co-design axes the paper fixes
//! (CAM geometry, ADC precision, stage-1 k, MAC count) and prints the
//! throughput / energy / recall trade surface — the tooling a team
//! adopting CAMformer would use to re-tune it for their workload.
//!
//! ```bash
//! cargo run --release --example dse_explorer [-- --n 1024]
//! ```

use anyhow::Result;
use camformer::accuracy::recall;
use camformer::arch::config::ArchConfig;
use camformer::arch::pipeline::PipelineModel;
use camformer::cost::system::{CamformerCost, SystemConfig};
use camformer::util::cli::Args;
use camformer::util::rng::Rng;
use camformer::util::table::Table;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("n", 1024);
    let mut rng = Rng::new(args.get_u64("seed", 42));

    // axis 1: stage-1 k — accuracy/recall vs sorter cost
    let mut t1 = Table::new(
        &format!("stage-1 k sweep (N={n}, g=16, Top-32)"),
        &["k1", "candidates", "weighted recall", "top32 passes"],
    );
    for k1 in [1usize, 2, 4, 8] {
        let w = recall::monte_carlo_weighted_recall_realistic(n, 8, 16, k1, 32, 150, &mut rng);
        let candidates = n / 16 * k1;
        t1.row(&[
            k1.to_string(),
            candidates.to_string(),
            format!("{w:.4}"),
            candidates.div_ceil(32).to_string(),
        ]);
    }
    t1.print();

    // axis 2: CAM geometry vs throughput and energy efficiency
    let mut t2 = Table::new(
        "CAM geometry sweep (1 GHz)",
        &["CAM_H x CAM_W", "qry/ms", "qry/mJ", "area mm^2"],
    );
    for cam_h in [8usize, 16, 32] {
        let sys = SystemConfig { cam_h, n, ..Default::default() };
        let cost = CamformerCost::evaluate(&sys);
        t2.row(&[
            format!("{cam_h}x64"),
            format!("{:.1}", cost.throughput_qry_per_ms),
            format!("{:.0}", cost.energy_eff_qry_per_mj),
            format!("{:.3}", cost.area_mm2),
        ]);
    }
    t2.print();

    // axis 3: MAC balance across context lengths
    let mut t3 = Table::new(
        "MAC balance vs context length",
        &["N", "assoc cycles", "MACs to balance", "pipelined qry/ms"],
    );
    for nn in [256usize, 512, 1024, 2048, 4096] {
        let cfg = ArchConfig { n: nn, ..Default::default() };
        let m = PipelineModel { cfg, fine_grained: true };
        t3.row(&[
            nn.to_string(),
            m.latencies().association.to_string(),
            m.balance_mac_units().to_string(),
            format!("{:.1}", m.throughput_qry_per_ms()),
        ]);
    }
    t3.print();

    // axis 4: ADC sharing — serialization vs area
    let mut t4 = Table::new(
        "ADC instances per array (association cadence ablation)",
        &["ADCs", "cycles/tile", "qry/ms"],
    );
    for adcs in [1usize, 2, 4, 8] {
        let cfg = ArchConfig { adcs_per_array: adcs, n, ..Default::default() };
        let m = PipelineModel { cfg, fine_grained: true };
        t4.row(&[
            adcs.to_string(),
            cfg.adc_cycles_per_tile().to_string(),
            format!("{:.1}", m.throughput_qry_per_ms()),
        ]);
    }
    t4.print();
    println!("\nthe paper's point (16x64, 6-bit shared SAR, k1=2, 8 MACs) balances all four axes.");
    Ok(())
}
