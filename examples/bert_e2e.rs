//! End-to-end validation driver (EXPERIMENTS.md §End-to-end).
//!
//! A real tiny transformer was trained (L2, `python/compile/train.py`) on
//! the associative-retrieval corpus; its weights are baked into the
//! classifier artifacts. This driver:
//!
//!   1. replays the training loss curve recorded at build time,
//!   2. measures task accuracy through PJRT for every attention variant
//!      (exact / single-stage HAD / two-stage CAMformer with k=1,2,4,8) —
//!      the Table III analogue, measured end-to-end,
//!   3. reports the serving-style latency of the classifier hot path.
//!
//! ```bash
//! make artifacts && cargo run --release --example bert_e2e [-- --trials 60]
//! ```

use anyhow::{Context, Result};
use std::time::Instant;

use camformer::accuracy::tables::measure_accuracy;
use camformer::runtime::executable::{default_artifacts_dir, Engine};
use camformer::util::cli::Args;
use camformer::util::table::Table;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let trials = args.get_usize("trials", 60);
    let dir = default_artifacts_dir();

    // graceful skip on a fresh checkout, mirroring the runtime tests:
    // the measurement needs the AOT artifacts and a PJRT-enabled build
    if !dir.join("manifest.tsv").exists() {
        println!(
            "bert_e2e: no artifacts at {dir:?} — run `make artifacts` (and build with \
             `--features pjrt`) to measure the Table III analogue; skipping."
        );
        return Ok(());
    }

    // 1. the recorded loss curve
    let log_path = dir.join("train_log.tsv");
    let log = std::fs::read_to_string(&log_path)
        .with_context(|| format!("{log_path:?} — run `make artifacts`"))?;
    println!("== training loss curve (recorded at build time) ==");
    let lines: Vec<&str> = log.lines().collect();
    for (i, line) in lines.iter().enumerate() {
        if i == 0 || i == lines.len() - 1 || i % 6 == 0 {
            println!("  {line}");
        }
    }

    // 2. measured accuracy per attention variant
    let mut engine = Engine::new(&dir)?;
    let variants: &[(&str, &str)] = &[
        ("exact attention (oracle)", "classifier_exact"),
        ("single-stage Top-32 (HAD)", "classifier_single_stage"),
        ("two-stage k=8", "classifier_cam_k8"),
        ("two-stage k=4", "classifier_cam_k4"),
        ("two-stage k=2 (Eq. 1)", "classifier_cam_k2"),
        ("two-stage k=1", "classifier_cam_k1"),
    ];
    let mut t = Table::new(
        &format!("measured accuracy, associative retrieval, {trials} sequences of 512 tokens"),
        &["attention variant", "accuracy %", "ms/seq"],
    );
    for (label, entry) in variants {
        let exe = engine.load(entry)?;
        let t0 = Instant::now();
        let acc = measure_accuracy(|toks| exe.run_s32(toks).expect("run"), 512, trials, 42);
        let ms = t0.elapsed().as_secs_f64() * 1e3 / trials as f64;
        t.row(&[label.to_string(), format!("{:.1}", acc * 100.0), format!("{ms:.1}")]);
    }
    t.print();
    println!("\nexpected pattern (paper Table III): near-baseline for k >= 2, visible drop at k = 1.");
    Ok(())
}
